"""Batched serving demo: prefill a batch of prompts, then decode with the KV
cache (MLS nearest-rounding quantized weights/activations at inference).

Run:  PYTHONPATH=src python examples/serve_lm.py --tokens 32 --batch 4
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import lm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-72b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params = lm.init_lm(jax.random.key(0), cfg)
    max_len = args.prompt_len + args.tokens
    prompts = jax.random.randint(
        jax.random.key(1), (args.batch, args.prompt_len), 0, cfg.vocab)

    print(f"serving reduced {cfg.name}: batch={args.batch} "
          f"prompt={args.prompt_len} gen={args.tokens}")
    t0 = time.perf_counter()
    logits, cache = jax.jit(
        lambda p, b: lm.prefill(p, b, cfg, max_len)
    )(params, {"tokens": prompts})
    print(f"prefill: {time.perf_counter()-t0:.2f}s "
          f"({args.batch * args.prompt_len} tokens)")

    decode = jax.jit(lambda p, c, t: lm.decode_step(p, c, t, cfg),
                     donate_argnums=(1,))
    tok = jnp.argmax(logits, -1)[:, None]
    out = [tok]
    t0 = time.perf_counter()
    for _ in range(args.tokens - 1):
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits, -1)[:, None]
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    n = args.batch * (args.tokens - 1)
    print(f"decode: {dt:.2f}s -> {n/dt:.1f} tok/s (batch={args.batch})")
    seqs = jnp.concatenate(out, axis=1)
    print("sample generations (token ids):")
    for row in seqs[:2]:
        print("  ", row[:16].tolist())


if __name__ == "__main__":
    main()
