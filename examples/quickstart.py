"""Quickstart: the MLS tensor format end to end.

1. dynamically quantize a tensor (paper Alg. 2) and inspect the three
   scaling levels,
2. run a low-bit matmul with the training semantics (paper Alg. 1),
3. run the Pallas quantized-domain kernel and check it is bit-identical to
   its pure-jnp oracle.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import (
    FMT_IMAGENET, GroupSpec, QuantConfig, average_relative_error,
    lowbit_matmul, mls_quantize,
)
from repro.kernels import lowbit_matmul_fused, mls_quantize_pallas, mls_matmul_pallas
from repro.kernels.ref import mls_matmul_ref


def main():
    key = jax.random.key(0)
    print(f"== 1. dynamic quantization to MLS {FMT_IMAGENET} ==")
    x = jax.random.normal(key, (8, 256)) * 10 ** jax.random.uniform(
        jax.random.fold_in(key, 1), (8, 1), minval=-2.0, maxval=1.0)
    t = mls_quantize(x, FMT_IMAGENET, GroupSpec((1, 128)))
    print(f"  tensor scale  S_t = {float(t.s_t):.4f}")
    print(f"  group scales  S_g = {jnp.round(t.s_g, 4)[:2]} ... "
          f"(<8,1> ceil-quantized, shape {t.s_g.shape})")
    print(f"  element codes: exp in [0,3], man in [0,15]; "
          f"packed {1 + FMT_IMAGENET.e + FMT_IMAGENET.m} bits/elem")
    are = float(average_relative_error(x, t.dequant()))
    are_pt = float(average_relative_error(
        x, mls_quantize(x, FMT_IMAGENET, None).dequant()))
    print(f"  ARE: group-wise={are:.4f}  vs per-tensor={are_pt:.4f} "
          f"(group scaling wins, paper Table IV)")

    print("== 2. low-bit training matmul (Alg. 1 semantics, STE grads) ==")
    w = jax.random.normal(jax.random.fold_in(key, 2), (256, 64)) * 0.05
    cfg = QuantConfig(fmt=FMT_IMAGENET)
    y = lowbit_matmul(x, w, jax.random.fold_in(key, 3), cfg)
    rel = float(jnp.linalg.norm(y - x @ w) / jnp.linalg.norm(x @ w))
    g = jax.grad(lambda w: lowbit_matmul(x, w, key, cfg).sum())(w)
    print(f"  fwd rel err vs fp32: {rel:.4f}; grad norm {float(jnp.linalg.norm(g)):.3f}")

    print("== 3. Pallas quantized-domain kernel vs oracle ==")
    xc, xsg, xst = mls_quantize_pallas(
        jnp.pad(x, ((0, 120), (0, 0))), FMT_IMAGENET, block_m=128)
    wc, wsgT, wst = mls_quantize_pallas(w.T, FMT_IMAGENET, block_m=64)
    yk = mls_matmul_pallas(xc, xsg, xst, wc.T, wsgT.T, wst, FMT_IMAGENET,
                           block_n=64)
    yr = mls_matmul_ref(xc, xsg, xst, wc.T, wsgT.T, wst, FMT_IMAGENET, 128)
    print(f"  kernel vs oracle bit-identical: {bool((yk == yr).all())}")
    yf = lowbit_matmul_fused(x, w, None, fmt=FMT_IMAGENET)
    rel = float(jnp.linalg.norm(yf - x @ w) / jnp.linalg.norm(x @ w))
    print(f"  fused kernel rel err vs fp32: {rel:.4f}")


if __name__ == "__main__":
    main()
