"""Train a small LM (reduced glm4-9b family) with MLS low-bit matmuls through
the full production stack: RunConfig -> make_train_step (grad accumulation,
clipping, schedules) -> checkpoint/restart.

Run:  PYTHONPATH=src python examples/train_lm_lowbit.py --steps 60
Scale up (real hardware): --layers 12 --d-model 768 gives a ~100M model.
"""
import argparse
import dataclasses
import tempfile

import jax

from repro.configs import get_smoke_config
from repro.configs.base import RunConfig, SHAPES
from repro.data import make_lm_iterator
from repro.models import lm
from repro.train import CheckpointManager, StragglerMonitor, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--no-quant", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke_config("glm4-9b")
    cfg = dataclasses.replace(
        cfg, n_layers=args.layers, d_model=args.d_model,
        d_ff=args.d_model * 3 // 2, vocab=1024, quant=not args.no_quant,
    )
    n = cfg.n_params()
    print(f"model: {cfg.name} reduced, {n/1e6:.1f}M params, quant={cfg.quant}")

    run = RunConfig(model=cfg, shape=SHAPES["train_4k"],
                    microbatch=args.microbatch, optimizer="adamw", lr=3e-3)
    train_step, opt_init = make_train_step(run)
    step = jax.jit(train_step, donate_argnums=(0, 1))

    params = lm.init_lm(jax.random.key(0), cfg)
    opt = opt_init(params)
    nxt, ds = make_lm_iterator(batch=args.batch, seq=args.seq, vocab=cfg.vocab)
    mon = StragglerMonitor()

    with tempfile.TemporaryDirectory() as td:
        mgr = CheckpointManager(td, keep=2)
        for i in range(args.steps):
            batch, ds = nxt(ds)
            mon.start()
            params, opt, m = step(params, opt, batch)
            dt = mon.stop()
            if (i + 1) % max(args.steps // 10, 1) == 0:
                print(f"  step {i+1}: loss={float(m['loss']):.3f} "
                      f"gnorm={float(m['grad_norm']):.2f} "
                      f"lr={float(m['lr']):.2e} ({dt:.2f}s)")
            if (i + 1) % 25 == 0:
                mgr.save(i + 1, {"params": params, "opt": opt, "data": ds},
                         blocking=False)
        mgr.wait()

        # fault-tolerance demo: restore and take one more step
        if mgr.latest_step():
            r = mgr.restore({"params": params, "opt": opt, "data": ds})
            b, _ = nxt(r["data"])
            _, _, m = step(r["params"], r["opt"], b)
            print(f"restored from step {mgr.latest_step()}, next-step "
                  f"loss={float(m['loss']):.3f} (restart-reproducible)")
    print(f"straggler steps flagged: {mon.report()['straggler_steps']}")


if __name__ == "__main__":
    main()
