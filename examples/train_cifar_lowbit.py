"""End-to-end driver (the paper's own experiment at reduced scale): train
ResNet-20 on (synthetic) CIFAR with the MLS low-bit training framework and
compare against the fp32 baseline — plus checkpoint/restart fault-tolerance
and straggler monitoring along the way.

Run:  PYTHONPATH=src python examples/train_cifar_lowbit.py --steps 200
(defaults are CPU-friendly; --width 1.0 --hw 32 --steps 1000 approaches the
real ResNet-20 setup.)
"""
import argparse
import tempfile

import jax
import jax.numpy as jnp

from repro.core import FMT_CIFAR, FMT_IMAGENET, QuantConfig
from repro.data import make_cifar_iterator
from repro.models.cnn import CNNConfig, apply_cnn, init_cnn
from repro.optim import sgdm_init, sgdm_update, step_decay_schedule
from repro.train import CheckpointManager, StragglerMonitor


def train(variant, qcfg, args, ckpt_dir=None):
    cfg = CNNConfig(arch="resnet20", num_classes=10,
                    width_mult=args.width, in_hw=args.hw)
    params = init_cnn(jax.random.key(0), cfg)
    opt = sgdm_init(params)
    nxt, ds = make_cifar_iterator(batch=args.batch, hw=args.hw)
    lr_fn = step_decay_schedule(0.05, [args.steps // 2, 3 * args.steps // 4])
    mgr = CheckpointManager(ckpt_dir, keep=2) if ckpt_dir else None
    mon = StragglerMonitor()

    @jax.jit
    def step(params, opt, batch, i):
        def loss_fn(p):
            logits = apply_cnn(p, batch["image"], cfg, qcfg,
                               jax.random.fold_in(jax.random.key(7), i))
            ll = jax.nn.log_softmax(logits)
            loss = -jnp.take_along_axis(ll, batch["label"][:, None], 1).mean()
            acc = (logits.argmax(-1) == batch["label"]).mean()
            return loss, acc

        (l, a), g = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt = sgdm_update(g, opt, params, lr_fn(opt.step))
        return params, opt, l, a

    accs = []
    for i in range(args.steps):
        batch, ds = nxt(ds)
        mon.start()
        params, opt, l, a = step(params, opt, batch, jnp.int32(i))
        dt = mon.stop()
        accs.append(float(a))
        if mgr and (i + 1) % 50 == 0:
            mgr.save(i + 1, {"params": params, "opt": opt, "data": ds},
                     blocking=False)
        if (i + 1) % max(args.steps // 10, 1) == 0:
            k = max(len(accs) // 5, 1)
            print(f"  [{variant}] step {i+1}: loss={float(l):.3f} "
                  f"acc(avg)={sum(accs[-k:])/k:.3f} ({dt:.2f}s/step)")
    if mgr:
        mgr.wait()
        print(f"  [{variant}] checkpoints: latest step {mgr.latest_step()}, "
              f"straggler report {mon.report()['straggler_steps']}")
    k = max(len(accs) // 5, 1)
    return sum(accs[-k:]) / k


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--hw", type=int, default=16)
    ap.add_argument("--width", type=float, default=0.5)
    ap.add_argument("--backend", choices=["fake_quant", "pallas"],
                    default="fake_quant",
                    help="arithmetic for the quantized convs/GEMMs: fake-quant "
                         "simulation or the quantized-domain Pallas kernels "
                         "(interpret mode on CPU: slow, use tiny --steps)")
    args = ap.parse_args()

    # the Pallas backend groups along im2col k-blocks; small blocks keep the
    # reduced CPU shapes from being all padding
    qkw = dict(backend=args.backend)
    if args.backend == "pallas":
        qkw["k_block"] = 32
    variants = [
        ("fp32", None),
        ("mls<2,4>", QuantConfig(fmt=FMT_IMAGENET, **qkw)),
        ("mls<2,1>", QuantConfig(fmt=FMT_CIFAR, **qkw)),
    ]
    results = {}
    with tempfile.TemporaryDirectory() as td:
        for name, qcfg in variants:
            print(f"== training {name} ==")
            results[name] = train(name, qcfg, args,
                                  ckpt_dir=f"{td}/{name}" if name != "fp32" else None)
    print("\n== final accuracy (paper Table II analogue) ==")
    base = results["fp32"]
    for name, acc in results.items():
        print(f"  {name:10s} acc={acc:.3f} drop={base - acc:+.3f}")


if __name__ == "__main__":
    main()
