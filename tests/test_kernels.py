"""Pallas kernel vs pure-jnp oracle: shape/dtype sweeps, bit-exactness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FMT_CIFAR, FMT_IMAGENET, EMFormat, QuantConfig, lowbit_matmul
from repro.kernels import lowbit_matmul_fused, mls_matmul_pallas, mls_quantize_pallas
from repro.kernels.ref import decode_frac_int, mls_matmul_ref, quantize_ref


@pytest.mark.parametrize("shape", [(128, 128), (256, 384), (64, 256)])
@pytest.mark.parametrize("fmt", [FMT_IMAGENET, FMT_CIFAR, EMFormat(2, 2)])
def test_quantize_kernel_matches_ref(shape, fmt):
    x = jax.random.normal(jax.random.key(0), shape) * 3.0
    bm = min(128, shape[0])
    codes_k, sg_k, st_k = mls_quantize_pallas(x, fmt, k_block=128, block_m=bm)
    r_u8 = jnp.full(shape, 127, dtype=jnp.uint8)
    codes_r, sg_r, st_r = quantize_ref(x, fmt, 128, r_u8=r_u8)
    np.testing.assert_array_equal(np.asarray(codes_k), np.asarray(codes_r))
    np.testing.assert_array_equal(np.asarray(sg_k), np.asarray(sg_r))
    assert float(st_k) == float(st_r)


def test_quantize_kernel_stochastic_reproducible():
    x = jax.random.normal(jax.random.key(1), (128, 256))
    a = mls_quantize_pallas(x, FMT_IMAGENET, key=jax.random.key(7))
    b = mls_quantize_pallas(x, FMT_IMAGENET, key=jax.random.key(7))
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    c = mls_quantize_pallas(x, FMT_IMAGENET, key=jax.random.key(8))
    assert np.any(np.asarray(a[0]) != np.asarray(c[0]))


@pytest.mark.parametrize("mnk", [(128, 128, 128), (128, 256, 384),
                                 (256, 128, 128)])
@pytest.mark.parametrize("fmt", [FMT_IMAGENET, FMT_CIFAR])
def test_matmul_kernel_bitexact_vs_ref(mnk, fmt):
    m, n, k = mnk
    x = jax.random.normal(jax.random.key(0), (m, k)) * 2
    w = jax.random.normal(jax.random.key(1), (k, n)) * 0.1
    xc, xsg, xst = mls_quantize_pallas(x, fmt, block_m=min(128, m))
    wc, wsgT, wst = mls_quantize_pallas(w.T, fmt, block_m=min(128, n))
    y_k = mls_matmul_pallas(xc, xsg, xst, wc.T, wsgT.T, wst, fmt)
    y_r = mls_matmul_ref(xc, xsg, xst, wc.T, wsgT.T, wst, fmt, 128)
    np.testing.assert_array_equal(np.asarray(y_k), np.asarray(y_r))


def test_decode_frac_int_bounds():
    """Decoded integer fractions respect the paper's §V-C bit-width."""
    fmt = FMT_IMAGENET
    codes = jnp.arange(256, dtype=jnp.uint8)
    f = np.asarray(decode_frac_int(codes, fmt))
    assert np.abs(f).max() < 2 ** (fmt.m + 2**fmt.e - 1)


@pytest.mark.parametrize("shape", [(100, 200, 72), (128, 128, 128),
                                   (33, 77, 190)])
def test_fused_matmul_padding_and_accuracy(shape):
    m, k, n = shape
    x = jax.random.normal(jax.random.key(2), (m, k))
    w = jax.random.normal(jax.random.key(3), (k, n)) * 0.1
    y = lowbit_matmul_fused(x, w, None, fmt=FMT_IMAGENET)
    assert y.shape == (m, n)
    yref = x @ w
    rel = float(jnp.linalg.norm(y - yref) / jnp.linalg.norm(yref))
    assert rel < 0.08, rel


def test_fused_matches_core_fakequant():
    """Kernel quantized-domain GEMM ~= core fake-quant path (same grouping;
    differences only from tie-rounding in the r-source representation)."""
    x = jax.random.normal(jax.random.key(4), (128, 256)) * 2
    w = jax.random.normal(jax.random.key(5), (256, 128)) * 0.05
    y_k = lowbit_matmul_fused(x, w, None, fmt=FMT_IMAGENET)
    cfg = QuantConfig(fmt=FMT_IMAGENET, stochastic=False, grouping="nc")
    y_c = lowbit_matmul(x, w, None, cfg)
    rel = float(jnp.linalg.norm(y_k - y_c) / jnp.linalg.norm(y_c))
    assert rel < 0.01, rel
