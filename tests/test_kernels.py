"""Pallas kernel vs pure-jnp oracle: shape/dtype sweeps, bit-exactness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FMT_CIFAR, FMT_IMAGENET, EMFormat, QuantConfig, lowbit_matmul
from repro.kernels import lowbit_matmul_fused, mls_matmul_pallas, mls_quantize_pallas
from repro.kernels.ref import decode_frac_int, mls_matmul_ref, quantize_ref


@pytest.mark.parametrize("shape", [(128, 128), (256, 384), (64, 256)])
@pytest.mark.parametrize("fmt", [FMT_IMAGENET, FMT_CIFAR, EMFormat(2, 2)])
def test_quantize_kernel_matches_ref(shape, fmt):
    x = jax.random.normal(jax.random.key(0), shape) * 3.0
    bm = min(128, shape[0])
    codes_k, sg_k, st_k = mls_quantize_pallas(x, fmt, k_block=128, block_m=bm)
    r_u8 = jnp.full(shape, 127, dtype=jnp.uint8)
    codes_r, sg_r, st_r = quantize_ref(x, fmt, 128, r_u8=r_u8)
    np.testing.assert_array_equal(np.asarray(codes_k), np.asarray(codes_r))
    np.testing.assert_array_equal(np.asarray(sg_k), np.asarray(sg_r))
    assert float(st_k) == float(st_r)


def test_quantize_kernel_stochastic_reproducible():
    x = jax.random.normal(jax.random.key(1), (128, 256))
    a = mls_quantize_pallas(x, FMT_IMAGENET, key=jax.random.key(7))
    b = mls_quantize_pallas(x, FMT_IMAGENET, key=jax.random.key(7))
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    c = mls_quantize_pallas(x, FMT_IMAGENET, key=jax.random.key(8))
    assert np.any(np.asarray(a[0]) != np.asarray(c[0]))


@pytest.mark.parametrize("mnk", [(128, 128, 128), (128, 256, 384),
                                 (256, 128, 128)])
@pytest.mark.parametrize("fmt", [FMT_IMAGENET, FMT_CIFAR])
def test_matmul_kernel_bitexact_vs_ref(mnk, fmt):
    m, n, k = mnk
    x = jax.random.normal(jax.random.key(0), (m, k)) * 2
    w = jax.random.normal(jax.random.key(1), (k, n)) * 0.1
    xc, xsg, xst = mls_quantize_pallas(x, fmt, block_m=min(128, m))
    wc, wsgT, wst = mls_quantize_pallas(w.T, fmt, block_m=min(128, n))
    y_k = mls_matmul_pallas(xc, xsg, xst, wc.T, wsgT.T, wst, fmt)
    y_r = mls_matmul_ref(xc, xsg, xst, wc.T, wsgT.T, wst, fmt, 128)
    np.testing.assert_array_equal(np.asarray(y_k), np.asarray(y_r))


def test_decode_frac_int_bounds():
    """Decoded integer fractions respect the paper's §V-C bit-width."""
    fmt = FMT_IMAGENET
    codes = jnp.arange(256, dtype=jnp.uint8)
    f = np.asarray(decode_frac_int(codes, fmt))
    assert np.abs(f).max() < 2 ** (fmt.m + 2**fmt.e - 1)


@pytest.mark.parametrize("shape", [(100, 200, 72), (128, 128, 128),
                                   (33, 77, 190)])
def test_fused_matmul_padding_and_accuracy(shape):
    m, k, n = shape
    x = jax.random.normal(jax.random.key(2), (m, k))
    w = jax.random.normal(jax.random.key(3), (k, n)) * 0.1
    y = lowbit_matmul_fused(x, w, None, fmt=FMT_IMAGENET)
    assert y.shape == (m, n)
    yref = x @ w
    rel = float(jnp.linalg.norm(y - yref) / jnp.linalg.norm(yref))
    assert rel < 0.08, rel


def test_fused_matches_core_fakequant():
    """Kernel quantized-domain GEMM ~= core fake-quant path (same grouping;
    differences only from tie-rounding in the r-source representation)."""
    x = jax.random.normal(jax.random.key(4), (128, 256)) * 2
    w = jax.random.normal(jax.random.key(5), (256, 128)) * 0.05
    y_k = lowbit_matmul_fused(x, w, None, fmt=FMT_IMAGENET)
    cfg = QuantConfig(fmt=FMT_IMAGENET, stochastic=False, grouping="nc")
    y_c = lowbit_matmul(x, w, None, cfg)
    rel = float(jnp.linalg.norm(y_k - y_c) / jnp.linalg.norm(y_c))
    assert rel < 0.01, rel


# ---------------------------------------------------------------------------
# grouping as a first-class kernel parameter (paper Table IV)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("grouping", ["nc", "c", "n", "none"])
def test_quantize_kernel_groupings_match_ref(grouping):
    x = jax.random.normal(jax.random.key(10), (96, 256)) * 3.0
    codes_k, sg_k, st_k = mls_quantize_pallas(
        x, FMT_IMAGENET, k_block=64, grouping=grouping)
    r_u8 = jnp.full(x.shape, 127, dtype=jnp.uint8)
    codes_r, sg_r, st_r = quantize_ref(
        x, FMT_IMAGENET, 64, r_u8=r_u8, grouping=grouping)
    assert sg_k.shape == sg_r.shape  # the grouping's compact layout
    np.testing.assert_array_equal(np.asarray(codes_k), np.asarray(codes_r))
    np.testing.assert_array_equal(np.asarray(sg_k), np.asarray(sg_r))
    assert float(st_k) == float(st_r)


@pytest.mark.parametrize("grouping", ["nc", "c", "n", "none"])
def test_fused_matmul_groupings_bitexact_vs_ref(grouping):
    from repro.kernels.lowbit_conv import REF_BACKEND, qd_gemm

    x = jax.random.normal(jax.random.key(11), (96, 256))
    w = jax.random.normal(jax.random.key(12), (256, 80)) * 0.1
    y_k = lowbit_matmul_fused(
        x, w, None, fmt=FMT_IMAGENET, k_block=64, block_m=64, block_n=64,
        grouping=grouping)
    y_r = qd_gemm(
        x, w, None, None, fmt=FMT_IMAGENET, k_block=64, block_m=64,
        block_n=64, grouping=grouping, backend=REF_BACKEND)
    np.testing.assert_array_equal(np.asarray(y_k), np.asarray(y_r))


def test_grouping_changes_executed_scale_layout():
    """A non-"nc" grouping must change the group-scale BlockSpecs of the
    *executed* Pallas GEMM, not just the python-level arrays."""
    from repro.analysis.kernel_verify import find_pallas_eqns

    def sg_block_shapes(grouping):
        def fn(x, w):
            return lowbit_matmul_fused(
                x, w, None, fmt=FMT_IMAGENET, k_block=64, block_m=64,
                block_n=64, grouping=grouping, interpret=True)
        cj = jax.make_jaxpr(fn)(
            jax.ShapeDtypeStruct((128, 256), jnp.float32),
            jax.ShapeDtypeStruct((256, 64), jnp.float32))
        gemm_eqn = find_pallas_eqns(cj.jaxpr)[-1]  # quantize, quantize, gemm
        gm = gemm_eqn.params["grid_mapping"]
        # operands: x_codes, x_sg, w_codes, w_sg, st
        return tuple(
            tuple(int(b) for b in gm.block_mappings[i].block_shape)
            for i in (1, 3))

    assert sg_block_shapes("nc") == ((64, 1), (1, 64))
    assert sg_block_shapes("c") == ((1, 1), (1, 1))
    assert sg_block_shapes("n") == ((64, 1), (1, 64))
    assert sg_block_shapes("none") == ((1, 1), (1, 1))
    # "n" delivers the same block shape as "nc" but from a (M, 1) array —
    # the full-array layouts must differ
    def sg_array_shapes(grouping):
        _, sg, _ = mls_quantize_pallas(
            jnp.ones((128, 256)), FMT_IMAGENET, 64, grouping=grouping)
        return tuple(sg.shape)

    assert sg_array_shapes("nc") == (128, 4)
    assert sg_array_shapes("n") == (128, 1)
    assert sg_array_shapes("c") == (1, 4)
    assert sg_array_shapes("none") == (1, 1)


# ---------------------------------------------------------------------------
# ragged shapes: pad-and-slice vs ValueError (the two failure-mode paths)
# ---------------------------------------------------------------------------
def test_matmul_kernel_ragged_mn_pads_and_slices():
    """Ragged M/N against the block tiling is handled exactly by
    pad-and-slice inside the kernel wrapper."""
    m, k, n = 100, 128, 72  # M, N not multiples of the 64-blocks
    x = jax.random.normal(jax.random.key(13), (m, k)) * 2
    w = jax.random.normal(jax.random.key(14), (k, n)) * 0.1
    xc, xsg, xst = mls_quantize_pallas(x, FMT_IMAGENET, 64, block_m=64)
    wc, wsgT, wst = mls_quantize_pallas(w.T, FMT_IMAGENET, 64, block_m=64)
    y = mls_matmul_pallas(
        xc, xsg, xst, wc.T, wsgT.T, wst, FMT_IMAGENET, k_block=64,
        block_m=64, block_n=64)
    assert y.shape == (m, n)
    y_r = mls_matmul_ref(xc, xsg, xst, wc.T, wsgT.T, wst, FMT_IMAGENET, 64)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y_r))


def test_matmul_kernel_ragged_k_raises_with_guidance():
    """K % k_block != 0 is a group-layout mismatch: a ValueError naming the
    shape, the block, and the nearest legal block."""
    xc = jnp.zeros((8, 100), jnp.uint8)
    wc = jnp.zeros((100, 8), jnp.uint8)
    with pytest.raises(ValueError) as e:
        mls_matmul_pallas(
            xc, jnp.ones((8, 1)), jnp.float32(1.0),
            wc, jnp.ones((1, 8)), jnp.float32(1.0),
            FMT_IMAGENET, k_block=64)
    msg = str(e.value)
    assert "K=100" in msg and "k_block=64" in msg and "50" in msg


def test_quantize_kernel_ragged_k_raises():
    with pytest.raises(ValueError, match="multiple of k_block"):
        mls_quantize_pallas(jnp.ones((8, 100)), FMT_IMAGENET, k_block=64)


def test_matmul_kernel_rejects_wrong_sg_layout():
    """Scales in the wrong compact layout for the grouping are rejected."""
    xc = jnp.zeros((64, 128), jnp.uint8)
    wc = jnp.zeros((128, 64), jnp.uint8)
    with pytest.raises(ValueError, match="layout mismatch"):
        mls_matmul_pallas(
            xc, jnp.ones((64, 2)), jnp.float32(1.0),  # "nc" x-layout
            wc, jnp.ones((2, 64)), jnp.float32(1.0),
            FMT_IMAGENET, k_block=64, grouping="c")  # but "c" requested
