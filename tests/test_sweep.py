"""Frontier sweep subsystem: grid expansion/dedup, config-hash stability,
gate semantics against planted regressions, sabotage negative controls,
smoke determinism and the CLI exit-code contract."""
import copy
import json

import pytest

from repro.sweep import __main__ as sweep_cli
from repro.sweep.gate import (
    apply_gate,
    build_baseline,
    sabotage_baseline,
)
from repro.sweep.grid import FORMATS, Cell, expand_grid, full_grid, smoke_grid
from repro.sweep.report import frontier_table
from repro.sweep.runner import run_cell


# ---------------------------------------------------------------------------
# grid expansion / dedup / hashing
# ---------------------------------------------------------------------------
def test_expand_grid_cartesian_product():
    cells = expand_grid([
        {"arch": ["resnet20"], "fmt": ["fp32", "mls_e2m1"],
         "backend": ["fake_quant", "pallas"], "steps": 4},
    ])
    assert len(cells) == 4
    assert {(c.fmt, c.backend) for c in cells} == {
        ("fp32", "fake_quant"), ("fp32", "pallas"),
        ("mls_e2m1", "fake_quant"), ("mls_e2m1", "pallas"),
    }


def test_expand_grid_dedups_overlapping_blocks():
    block = {"arch": "resnet20", "fmt": "mls_e2m1", "steps": 4}
    cells = expand_grid([block, dict(block), {**block, "envelope_acc": 0.5}])
    # the third block differs only in a gate tolerance -> same math, deduped
    assert len(cells) == 1


def test_config_hash_stable_and_semantic():
    c = Cell(arch="resnet20", fmt="mls_e2m1", steps=4)
    # committed-stability check: baselines key on this digest, so a silent
    # change to the hash domain must show up as a test failure
    assert c.config_hash() == Cell(arch="resnet20", fmt="mls_e2m1",
                                   steps=4).config_hash()
    assert c.config_hash() != Cell(arch="resnet20", fmt="mls_e2m4",
                                   steps=4).config_hash()
    assert c.config_hash() != Cell(arch="resnet20", fmt="mls_e2m1",
                                   steps=5).config_hash()
    # tolerances are gate config, not math: hash-invariant
    assert c.config_hash() == Cell(arch="resnet20", fmt="mls_e2m1", steps=4,
                                   envelope_acc=0.1).config_hash()


def test_cell_validation():
    with pytest.raises(ValueError):
        Cell(arch="resnet20", fmt="bf16")
    with pytest.raises(ValueError):
        Cell(arch="alexnet", fmt="fp32")
    with pytest.raises(ValueError):
        Cell(arch="resnet20", fmt="fp32", backend="cuda")


def test_smoke_grid_meets_acceptance_floor():
    """ISSUE acceptance: >= 12 cells, >= 3 formats x >= 3 archs, both
    backends; hashes unique by construction."""
    cells = smoke_grid()
    assert len(cells) >= 12
    assert len({c.fmt for c in cells}) >= 3
    assert len({c.arch for c in cells}) >= 3
    assert {c.backend for c in cells} == {"fake_quant", "pallas"}
    hashes = [c.config_hash() for c in cells]
    assert len(hashes) == len(set(hashes))


def test_full_grid_superset_axes():
    cells = full_grid()
    assert {c.backend for c in cells} == {"fake_quant", "pallas"}
    assert "none" in {c.grouping for c in cells}  # Table IV ablation axis
    assert len({c.fmt for c in cells}) >= 4


def test_grids_have_fp32_reference_for_envelopes():
    for name, cells in (("smoke", smoke_grid()), ("full", full_grid())):
        rows = [{"arch": c.arch, "fmt": c.fmt, "backend": c.backend,
                 "grouping": c.grouping} for c in cells]
        for c in cells:
            if c.envelope_acc is None and c.envelope_loss is None:
                continue
            assert any(r["arch"] == c.arch and r["fmt"] == "fp32"
                       and r["backend"] == "fake_quant" for r in rows), \
                (name, c.cell_id())


# ---------------------------------------------------------------------------
# gate semantics (no training: synthetic rows)
# ---------------------------------------------------------------------------
def _mk_row(cell_id="resnet20/mls_e2m1/fake_quant", h="abc123", loss=1.0,
            acc=0.6, diverged=False, **extra):
    arch, fmt, backend = cell_id.split("/")[:3]
    row = {"name": f"sweep/{cell_id}", "cell_id": cell_id, "config_hash": h,
           "arch": arch, "fmt": fmt, "backend": backend, "grouping": "nc",
           "steps": 4, "final_loss": loss, "final_acc": acc,
           "diverged": diverged, "wall_time_s": 1.0}
    row.update(extra)
    return row


def _mk_baseline(rows, grid="smoke"):
    return build_baseline(rows, grid)


def test_gate_passes_on_identical_run():
    rows = [_mk_row(), _mk_row("transformer/fp32/fake_quant", "def456",
                               loss=6.0, acc=None)]
    assert apply_gate(rows, _mk_baseline(rows), grid_name="smoke") == []


def test_gate_fails_on_planted_loss_regression():
    rows = [_mk_row(loss=1.0)]
    base = _mk_baseline(rows)
    regressed = [_mk_row(loss=1.6)]  # > 1.0 + default tol 0.25
    fails = apply_gate(regressed, base, grid_name="smoke")
    assert len(fails) == 1 and "final_loss" in fails[0]


def test_gate_fails_on_planted_acc_regression():
    rows = [_mk_row(acc=0.8)]
    fails = apply_gate([_mk_row(acc=0.5)], _mk_baseline(rows),
                       grid_name="smoke")
    assert len(fails) == 1 and "final_acc" in fails[0]


def test_gate_respects_per_cell_tolerance_override():
    rows = [_mk_row(loss=1.0)]
    base = _mk_baseline(rows)
    base["cells"]["abc123"]["loss_tol"] = 1.0
    assert apply_gate([_mk_row(loss=1.6)], base, grid_name="smoke") == []


def test_gate_fails_on_new_divergence_but_allows_known():
    healthy = [_mk_row()]
    fails = apply_gate([_mk_row(diverged=True)], _mk_baseline(healthy),
                       grid_name="smoke")
    assert len(fails) == 1 and "diverged" in fails[0]
    # a cell blessed as diverged (fixed point Ex=0) may stay diverged
    known_bad = [_mk_row(diverged=True)]
    assert apply_gate(known_bad, _mk_baseline(known_bad),
                      grid_name="smoke") == []


def test_gate_fails_on_unknown_and_missing_cells():
    rows = [_mk_row()]
    base = _mk_baseline(rows)
    unknown = [_mk_row(h="fresh999")]
    fails = apply_gate(unknown, base, grid_name="smoke")
    assert any("not in baseline" in f for f in fails)
    assert any("missing from the run" in f for f in fails)
    # partial (--only) runs skip the reverse-coverage check
    assert not any("missing from the run" in f
                   for f in apply_gate(unknown, base, grid_name=None))


def test_gate_envelope_against_same_run_fp32():
    fp32 = _mk_row("resnet20/fp32/fake_quant", "f32f32", loss=0.5, acc=0.9)
    ok = _mk_row(loss=1.0, acc=0.75, envelope_acc=0.2)
    bad = _mk_row(loss=1.0, acc=0.65, envelope_acc=0.2)
    base = _mk_baseline([fp32, ok])
    assert apply_gate([fp32, ok], base, grid_name="smoke") == []
    base_bad = _mk_baseline([fp32, bad])
    fails = apply_gate([fp32, bad], base_bad, grid_name="smoke")
    assert len(fails) == 1 and "envelope" in fails[0]


def test_sabotage_modes_fail_a_healthy_run():
    rows = [_mk_row(), _mk_row("transformer/fp32/fake_quant", "def456",
                               loss=6.0, acc=None)]
    base = _mk_baseline(rows)
    assert apply_gate(rows, base, grid_name="smoke") == []
    for mode in ("regress", "missing_cell"):
        sab = sabotage_baseline(base, mode)
        assert apply_gate(rows, sab, grid_name="smoke"), mode
    with pytest.raises(ValueError):
        sabotage_baseline(base, "nope")
    # sabotage never mutates the real baseline in place
    assert apply_gate(rows, base, grid_name="smoke") == []


def test_build_baseline_merges_grids_and_drops_stale():
    smoke_rows = [_mk_row(h="aaa"), _mk_row(h="bbb")]
    base = build_baseline(smoke_rows, "smoke")
    base = build_baseline([_mk_row(h="bbb"), _mk_row(h="ccc")], "full", base)
    assert set(base["cells"]) == {"aaa", "bbb", "ccc"}
    assert base["cells"]["bbb"]["grids"] == ["full", "smoke"]
    # re-blessing smoke without "aaa" drops it
    base = build_baseline([_mk_row(h="bbb")], "smoke", base)
    assert "aaa" not in base["cells"]
    assert base["cells"]["ccc"]["grids"] == ["full"]


def test_committed_baseline_covers_both_grids():
    """The committed baseline must bless exactly the committed grids."""
    from repro.sweep.gate import load_baseline
    base = load_baseline()
    assert base["schema_version"] == 1
    for name, cells in (("smoke", smoke_grid()), ("full", full_grid())):
        for c in cells:
            entry = base["cells"].get(c.config_hash())
            assert entry is not None, (name, c.cell_id())
            assert name in entry["grids"], (name, c.cell_id())


# ---------------------------------------------------------------------------
# runner determinism (one real tiny cell, trained twice)
# ---------------------------------------------------------------------------
def test_smoke_cell_deterministic_under_seeds():
    cell = Cell(arch="resnet20", fmt="mls_e2m1", steps=3, batch=4, hw=8)
    r1, r2 = run_cell(cell), run_cell(cell)
    assert r1["final_loss"] == r2["final_loss"]
    assert r1["final_acc"] == r2["final_acc"]
    assert r1["config_hash"] == r2["config_hash"]
    assert not r1["diverged"]


# ---------------------------------------------------------------------------
# report + CLI
# ---------------------------------------------------------------------------
def test_frontier_table_pivot():
    rows = [_mk_row(), _mk_row("resnet20/fp32/fake_quant", "f32f32",
                               loss=0.5, acc=0.9),
            _mk_row("mamba2/mls_e2m4/pallas", "mmm111", loss=6.0, acc=None,
                    diverged=True)]
    md = frontier_table(rows)
    assert "| resnet20 | fake_quant |" in md
    assert "acc 0.600" in md and "acc 0.900" in md
    assert "**DIVERGED**" in md
    # every swept format that appears gets a column
    assert "`mls_e2m1`" in md and "`mls_e2m4`" in md
    assert all(f in FORMATS for f in ("fp32", "mls_e2m1"))


def test_cli_gate_exit_codes(tmp_path):
    rows = [_mk_row()]
    base = _mk_baseline(rows)
    bpath = tmp_path / "baseline.json"
    bpath.write_text(json.dumps(base))
    payload = {"suite": "frontier_sweep", "grid": "smoke", "rows": rows}
    rpath = tmp_path / "BENCH_accuracy.json"
    rpath.write_text(json.dumps(payload))

    assert sweep_cli.main(["--gate", "--from", str(rpath),
                           "--baseline", str(bpath)]) == 0
    assert sweep_cli.main(["--gate", "--sabotage", "--from", str(rpath),
                           "--baseline", str(bpath)]) == 1
    # regression in the rows themselves
    bad = copy.deepcopy(payload)
    bad["rows"][0]["final_loss"] = 9.0
    bad["rows"][0]["diverged"] = True
    rbad = tmp_path / "bad.json"
    rbad.write_text(json.dumps(bad))
    assert sweep_cli.main(["--gate", "--from", str(rbad),
                           "--baseline", str(bpath)]) == 1
    # without --gate the same failures only report (exit 0)
    assert sweep_cli.main(["--from", str(rbad), "--baseline", str(bpath)]) == 0


def test_cli_only_validation_and_list(capsys):
    assert sweep_cli.main(["--smoke", "--only", "definitely-not-a-cell",
                           "--list"]) == 2
    assert "matches no cell" in capsys.readouterr().err
    assert sweep_cli.main(["--smoke", "--list"]) == 0
    out = capsys.readouterr().out
    assert "resnet20/mls_e2m1/fake_quant" in out


def test_cli_update_baseline_refuses_partial_and_sabotage(tmp_path, capsys):
    rows = [_mk_row()]
    payload = {"suite": "frontier_sweep", "grid": "smoke", "rows": rows}
    rpath = tmp_path / "rows.json"
    rpath.write_text(json.dumps(payload))
    bpath = tmp_path / "b.json"
    assert sweep_cli.main(["--from", str(rpath), "--sabotage",
                           "--update-baseline",
                           "--baseline", str(bpath)]) == 2
    assert sweep_cli.main(["--from", str(rpath), "--update-baseline",
                           "--baseline", str(bpath)]) == 0
    assert json.loads(bpath.read_text())["cells"]["abc123"]["final_loss"] == 1.0


# ---------------------------------------------------------------------------
# benchmarks satellites: run.py --only validation, _record stamping
# ---------------------------------------------------------------------------
def test_bench_run_only_validation():
    from benchmarks import run as bench_run
    with pytest.raises(SystemExit) as exc:
        bench_run.main(["--only", "tabel2"])  # typo must not run-nothing-green
    assert exc.value.code == 2


def test_record_stamps_schema_and_sha():
    from repro.sweep.record import SCHEMA_VERSION, make_payload
    payload = make_payload("test_suite", [{"name": "a"}, {"name": "b"}],
                           quick=True, extra={"grid": "smoke"})
    assert payload["suite"] == "test_suite"
    assert payload["schema_version"] == SCHEMA_VERSION
    assert payload["grid"] == "smoke"
    assert isinstance(payload["git_sha"], str) and payload["git_sha"]
    for row in payload["rows"]:
        assert row["schema_version"] == SCHEMA_VERSION
        assert row["git_sha"] == payload["git_sha"]
