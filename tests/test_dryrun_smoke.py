"""End-to-end dry-run smoke: one real cell compiled on the 512-device
production mesh in a subprocess (keeps this process at 1 device)."""
import json
import os
import subprocess
import sys

_SCRIPT = r"""
from repro.launch.dryrun import run_cell
r = run_cell("mamba2-370m", "long_500k", multi_pod=True, out_dir="/tmp/dryrun_test",
             tag="smoke", verbose=False)
assert r["hlo"]["coll_bytes"] > 0
assert r["roofline"]["bottleneck"] in ("compute", "memory", "collective")
print("CELL_OK", r["mesh"], r["roofline"]["bottleneck"])
"""


def test_dryrun_cell_compiles_multipod():
    r = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True, text=True, timeout=1200,
        env={"PYTHONPATH": "src", "PATH": os.environ.get("PATH", ""),
             "HOME": "/root"},
        cwd="/root/repo",
    )
    assert "CELL_OK 2x16x16" in r.stdout, (r.stdout[-2000:], r.stderr[-2000:])
    rec = json.load(open("/tmp/dryrun_test/mamba2-370m_long_500k_2x16x16_smoke.json"))
    assert rec["n_devices"] == 512
    assert rec["memory_analysis"]["temp_bytes"] is not None


def test_sp_rules_preserve_semantics():
    """Sequence-parallel rules are a layout change only: loss identical (up
    to fp reassociation) on a 4-device mesh vs unsharded."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import dataclasses, jax, jax.numpy as jnp
from repro.configs import get_smoke_config
from repro.models import lm
from repro.parallel import axis_rules
from repro.parallel.sharding import SP_RULES

cfg = dataclasses.replace(get_smoke_config("glm4-9b"), quant=False,
                          n_heads=4, n_kv_heads=4)
p = lm.init_lm(jax.random.key(0), cfg)
batch = {"tokens": jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab)}
l0, _ = lm.lm_loss(p, batch, cfg, None)
from repro.launch.mesh import make_mesh
mesh = make_mesh((2, 2), ("data", "model"))
with mesh, axis_rules(SP_RULES, mesh):
    l1, _ = jax.jit(lambda p, b: lm.lm_loss(p, b, cfg, None))(p, batch)
err = abs(float(l0) - float(l1))
assert err < 1e-4, (float(l0), float(l1))
print("SP_OK", err)
"""
    r = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": os.environ.get("PATH", ""),
             "HOME": "/root"},
        cwd="/root/repo",
    )
    assert "SP_OK" in r.stdout, (r.stdout[-2000:], r.stderr[-2000:])
