"""Quantized-domain fused conv/matmul (kernels.lowbit_conv) vs jnp oracle.

The oracle runs the *same* im2col/padding layout code with the pure-jnp
quantize/matmul references, so every comparison here is bit-exact — it
checks that the Pallas kernels implement the quantized-domain arithmetic
identically, across stride/padding/odd-channel cases and both paper formats.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FMT_CIFAR, FMT_IMAGENET, QuantConfig
from repro.kernels import (
    conv_fused_grads_ref,
    lowbit_conv_fused,
    lowbit_conv_fused_ref,
    lowbit_matmul_qd,
    matmul_qd_grads_ref,
    matmul_qd_ref,
)


def _cfg(fmt, **kw):
    kw.setdefault("k_block", 32)
    kw.setdefault("stochastic", False)
    return QuantConfig(fmt=fmt, backend="pallas", **kw)


CASES = [
    # (N, C, H/W, O, ksize, stride, padding) — odd channels, stride, pads
    (2, 5, 9, 7, 3, (1, 1), "SAME"),
    (2, 5, 9, 7, 3, (2, 2), "VALID"),
    (1, 3, 8, 4, 1, (1, 1), "SAME"),
    (2, 4, 10, 6, 3, (2, 1), "SAME"),
    (1, 7, 7, 5, 5, (1, 1), [(2, 2), (2, 2)]),
]


@pytest.mark.parametrize("fmt", [FMT_IMAGENET, FMT_CIFAR])
@pytest.mark.parametrize("case", CASES[:2])
def test_conv_fused_forward_bitexact_formats(fmt, case):
    n, c, hw, o, k, stride, pad = case
    cfg = _cfg(fmt)
    x = jax.random.normal(jax.random.key(0), (n, c, hw, hw)) * 2
    w = jax.random.normal(jax.random.key(1), (o, c, k, k)) * 0.2
    y = lowbit_conv_fused(x, w, None, stride, pad, cfg)
    y_ref = lowbit_conv_fused_ref(x, w, None, stride, pad, cfg)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y_ref))


@pytest.mark.parametrize("case", CASES)
def test_conv_fused_grads_bitexact(case):
    n, c, hw, o, k, stride, pad = case
    cfg = _cfg(FMT_IMAGENET)
    x = jax.random.normal(jax.random.key(2), (n, c, hw, hw))
    w = jax.random.normal(jax.random.key(3), (o, c, k, k)) * 0.2
    y = lowbit_conv_fused(x, w, None, stride, pad, cfg)
    g = jax.random.normal(jax.random.key(4), y.shape)
    dx, dw = jax.grad(
        lambda a, b: (lowbit_conv_fused(a, b, None, stride, pad, cfg) * g).sum(),
        argnums=(0, 1),
    )(x, w)
    dx_ref, dw_ref = conv_fused_grads_ref(x, w, g, None, stride, pad, cfg)
    np.testing.assert_array_equal(np.asarray(dx), np.asarray(dx_ref))
    np.testing.assert_array_equal(np.asarray(dw), np.asarray(dw_ref))


def test_conv_fused_grads_bitexact_cifar_fmt():
    cfg = _cfg(FMT_CIFAR)
    x = jax.random.normal(jax.random.key(5), (2, 5, 9, 9))
    w = jax.random.normal(jax.random.key(6), (7, 5, 3, 3)) * 0.2
    y = lowbit_conv_fused(x, w, None, (1, 1), "SAME", cfg)
    g = jax.random.normal(jax.random.key(7), y.shape)
    dx, dw = jax.grad(
        lambda a, b: (lowbit_conv_fused(a, b, None, (1, 1), "SAME", cfg) * g).sum(),
        argnums=(0, 1),
    )(x, w)
    dx_ref, dw_ref = conv_fused_grads_ref(x, w, g, None, (1, 1), "SAME", cfg)
    np.testing.assert_array_equal(np.asarray(dx), np.asarray(dx_ref))
    np.testing.assert_array_equal(np.asarray(dw), np.asarray(dw_ref))


def test_conv_fused_stochastic_bitexact_and_reproducible():
    """Stochastic rounding consumes the same uint8 draws in kernel and ref."""
    cfg = _cfg(FMT_IMAGENET, stochastic=True)
    x = jax.random.normal(jax.random.key(0), (2, 5, 8, 8))
    w = jax.random.normal(jax.random.key(1), (6, 5, 3, 3)) * 0.2
    k = jax.random.key(11)
    y1 = lowbit_conv_fused(x, w, k, (1, 1), "SAME", cfg)
    y2 = lowbit_conv_fused(x, w, k, (1, 1), "SAME", cfg)
    y_ref = lowbit_conv_fused_ref(x, w, k, (1, 1), "SAME", cfg)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y_ref))
    y3 = lowbit_conv_fused(x, w, jax.random.key(12), (1, 1), "SAME", cfg)
    assert np.any(np.asarray(y1) != np.asarray(y3))


def test_conv_fused_tracks_fp32_conv():
    cfg = _cfg(FMT_IMAGENET)
    x = jax.random.normal(jax.random.key(8), (2, 8, 12, 12))
    w = jax.random.normal(jax.random.key(9), (12, 8, 3, 3)) * 0.1
    y = lowbit_conv_fused(x, w, None, (1, 1), "SAME", cfg)
    y_fp = jax.lax.conv_general_dilated(
        x, w, (1, 1), "SAME", dimension_numbers=("NCHW", "OIHW", "NCHW")
    )
    rel = float(jnp.linalg.norm(y - y_fp) / jnp.linalg.norm(y_fp))
    assert rel < 0.08, rel


def test_matmul_qd_bitexact_fwd_and_grads():
    cfg = _cfg(FMT_IMAGENET)
    x = jax.random.normal(jax.random.key(0), (3, 20, 50))
    w = jax.random.normal(jax.random.key(1), (50, 30)) * 0.1
    y = lowbit_matmul_qd(x, w, None, cfg)
    np.testing.assert_array_equal(
        np.asarray(y), np.asarray(matmul_qd_ref(x, w, None, cfg))
    )
    g = jax.random.normal(jax.random.key(2), y.shape)
    dx, dw = jax.grad(
        lambda a, b: (lowbit_matmul_qd(a, b, None, cfg) * g).sum(), (0, 1)
    )(x, w)
    dx_ref, dw_ref = matmul_qd_grads_ref(x, w, g, None, cfg)
    np.testing.assert_array_equal(np.asarray(dx), np.asarray(dx_ref))
    np.testing.assert_array_equal(np.asarray(dw), np.asarray(dw_ref))


def test_backend_validation():
    with pytest.raises(ValueError):
        QuantConfig(backend="nope")


def _train_losses(backend: str, steps: int = 2):
    """Reduced ResNet-20, identical data/init/keys; only the backend varies."""
    from repro.models.cnn import CNNConfig, apply_cnn, init_cnn
    from repro.optim import sgdm_init, sgdm_update

    cfg = CNNConfig(arch="resnet20", num_classes=10, width_mult=0.25, in_hw=8)
    qcfg = QuantConfig(
        fmt=FMT_IMAGENET, stochastic=False, backend=backend, k_block=32
    )
    params = init_cnn(jax.random.key(0), cfg)
    opt = sgdm_init(params)
    x = jax.random.normal(jax.random.key(1), (4, 3, 8, 8))
    labels = jnp.array([0, 1, 2, 3])

    @jax.jit
    def step(params, opt):
        def loss_fn(p):
            logits = apply_cnn(p, x, cfg, qcfg, None)
            ll = jax.nn.log_softmax(logits)
            return -jnp.take_along_axis(ll, labels[:, None], 1).mean()

        l, g = jax.value_and_grad(loss_fn)(params)
        params, opt = sgdm_update(g, opt, params, lr=0.05)
        return params, opt, l

    losses = []
    for _ in range(steps):
        params, opt, l = step(params, opt)
        losses.append(float(l))
    return losses


def test_resnet20_pallas_backend_matches_fake_quant():
    """2-step smoke train: quantized-domain arithmetic tracks fake-quant.

    The two backends use different scaling-group layouts (conv (n,c) vs
    im2col k-blocks), so losses agree approximately, not bitwise.
    """
    l_fq = _train_losses("fake_quant")
    l_pl = _train_losses("pallas")
    assert all(np.isfinite(l_pl)), l_pl
    for a, b in zip(l_fq, l_pl):
        assert abs(a - b) < 0.15 * max(1.0, abs(a)), (l_fq, l_pl)


@pytest.mark.parametrize("grouping", ["c", "n", "none"])
def test_conv_fused_groupings_bitexact(grouping):
    """QuantConfig.grouping flows through to the Pallas conv kernels: each
    non-"nc" layout still matches the oracle bit-for-bit (the oracle uses
    the same grouping), and differs from the "nc" output."""
    cfg = _cfg(FMT_IMAGENET, grouping=grouping)
    x = jax.random.normal(jax.random.key(20), (2, 5, 9, 9))
    w = jax.random.normal(jax.random.key(21), (7, 5, 3, 3)) * 0.2
    y = lowbit_conv_fused(x, w, None, (1, 1), "SAME", cfg)
    y_ref = lowbit_conv_fused_ref(x, w, None, (1, 1), "SAME", cfg)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y_ref))
    y_nc = lowbit_conv_fused(x, w, None, (1, 1), "SAME",
                             _cfg(FMT_IMAGENET, grouping="nc"))
    assert np.any(np.asarray(y) != np.asarray(y_nc))


def test_conv_fused_explicit_blocks_override_cache():
    """cfg.block_m/block_n pin the GEMM tiling (explicit > cache) and do
    not change the math."""
    cfg_a = _cfg(FMT_IMAGENET, block_m=32, block_n=32)
    cfg_b = _cfg(FMT_IMAGENET)  # cache/default resolution
    x = jax.random.normal(jax.random.key(22), (1, 4, 8, 8))
    w = jax.random.normal(jax.random.key(23), (6, 4, 3, 3)) * 0.2
    y_a = lowbit_conv_fused(x, w, None, (1, 1), "SAME", cfg_a)
    y_b = lowbit_conv_fused(x, w, None, (1, 1), "SAME", cfg_b)
    np.testing.assert_array_equal(np.asarray(y_a), np.asarray(y_b))
