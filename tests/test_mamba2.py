"""SSD chunked algorithm vs a naive step-by-step recurrence oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.mamba2 import ssd_chunked


def naive_ssm(x, a, bm, cm):
    """y_t = C_t · h_t;  h_t = exp(a_t) h_{t-1} + B_t x_t  (per head)."""
    b, s, h, p = x.shape
    g, n = bm.shape[2], bm.shape[3]
    rep = h // g
    bmh = np.repeat(np.asarray(bm), rep, axis=2)
    cmh = np.repeat(np.asarray(cm), rep, axis=2)
    x, a = np.asarray(x, np.float64), np.asarray(a, np.float64)
    hstate = np.zeros((b, h, p, n))
    ys = np.zeros((b, s, h, p))
    for t in range(s):
        hstate = np.exp(a[:, t])[:, :, None, None] * hstate + \
            np.einsum("bhn,bhp->bhpn", bmh[:, t], x[:, t])
        ys[:, t] = np.einsum("bhpn,bhn->bhp", hstate, cmh[:, t])
    return ys, hstate


@pytest.mark.parametrize("chunk", [4, 8, 16])
@pytest.mark.parametrize("groups", [1, 2])
def test_ssd_matches_naive(chunk, groups):
    key = jax.random.key(0)
    b, s, h, p, n = 2, 16, 4, 8, 16
    x = jax.random.normal(jax.random.fold_in(key, 0), (b, s, h, p))
    a = -jnp.abs(jax.random.normal(jax.random.fold_in(key, 1), (b, s, h))) * 0.5
    bm = jax.random.normal(jax.random.fold_in(key, 2), (b, s, groups, n)) * 0.3
    cm = jax.random.normal(jax.random.fold_in(key, 3), (b, s, groups, n)) * 0.3
    y, st = ssd_chunked(x, a, bm, cm, chunk)
    yr, str_ = naive_ssm(x, a, bm, cm)
    np.testing.assert_allclose(np.asarray(y), yr, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st), str_, rtol=2e-4, atol=2e-4)


def test_ssd_chunk_invariance():
    key = jax.random.key(1)
    b, s, h, p, n = 1, 32, 2, 4, 8
    x = jax.random.normal(jax.random.fold_in(key, 0), (b, s, h, p))
    a = -jnp.abs(jax.random.normal(jax.random.fold_in(key, 1), (b, s, h)))
    bm = jax.random.normal(jax.random.fold_in(key, 2), (b, s, 1, n)) * 0.3
    cm = jax.random.normal(jax.random.fold_in(key, 3), (b, s, 1, n)) * 0.3
    y8, _ = ssd_chunked(x, a, bm, cm, 8)
    y16, _ = ssd_chunked(x, a, bm, cm, 16)
    y32, _ = ssd_chunked(x, a, bm, cm, 32)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y16), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y32), rtol=1e-4, atol=1e-5)


def test_ssd_initial_state_chaining():
    """Processing [first half] then [second half | state] == full pass."""
    key = jax.random.key(2)
    b, s, h, p, n = 1, 16, 2, 4, 8
    x = jax.random.normal(jax.random.fold_in(key, 0), (b, s, h, p))
    a = -jnp.abs(jax.random.normal(jax.random.fold_in(key, 1), (b, s, h)))
    bm = jax.random.normal(jax.random.fold_in(key, 2), (b, s, 1, n)) * 0.3
    cm = jax.random.normal(jax.random.fold_in(key, 3), (b, s, 1, n)) * 0.3
    y_full, st_full = ssd_chunked(x, a, bm, cm, 8)
    y1, st1 = ssd_chunked(x[:, :8], a[:, :8], bm[:, :8], cm[:, :8], 8)
    y2, st2 = ssd_chunked(x[:, 8:], a[:, 8:], bm[:, 8:], cm[:, 8:], 8, st1)
    np.testing.assert_allclose(np.asarray(y_full[:, :8]), np.asarray(y1),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(y_full[:, 8:]), np.asarray(y2),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(st_full), np.asarray(st2),
                               rtol=1e-4, atol=1e-5)
