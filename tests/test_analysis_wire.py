"""Wire-byte budget audit: the compiled compressed-gradient ring must beat
fp32 by >= 3.5x (subprocess with 2 forced host devices, like
test_compress)."""
import subprocess
import sys

_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
from repro.analysis.wire import audit_wire_ring

r = audit_wire_ring(n_elems=1 << 14)
print("RATIO", r["compression_ratio"])
assert r["compression_ratio"] >= 3.5, r
assert r["n_collective_permutes"] >= 3  # codes + group scales + tensor scale
by_dt = r["wire_bytes_by_dtype"]
# uint8 code payload must dominate the wire; fp32 is only the tiny scales
assert by_dt.get("u8", 0.0) > 10 * by_dt.get("f32", 0.0), by_dt
print("OK")
"""


def test_compressed_ring_wire_budget_subprocess():
    r = subprocess.run(
        [sys.executable, "-c", _SUBPROC], capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo", timeout=600,
    )
    assert "OK" in r.stdout, (r.stdout, r.stderr)
