"""Per-architecture smoke tests (deliverable f): reduced same-family config,
one forward/train step on CPU, asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config, get_smoke_config, runnable_shapes
from repro.models import lm

ALL = sorted(ARCHS)


def make_batch(cfg, b=2, s=32):
    batch = {"tokens": jax.random.randint(jax.random.key(0), (b, s), 0, cfg.vocab)}
    if cfg.frontend != "none" and cfg.family != "encdec":
        batch["frontend_emb"] = jax.random.normal(
            jax.random.key(1), (b, cfg.frontend_len, cfg.frontend_dim))
    if cfg.family == "encdec":
        batch["src_emb"] = jax.random.normal(
            jax.random.key(1), (b, s, cfg.frontend_dim))
    return batch


@pytest.mark.parametrize("name", ALL)
def test_smoke_train_step(name):
    cfg = get_smoke_config(name)
    p = lm.init_lm(jax.random.key(0), cfg)
    batch = make_batch(cfg)
    (loss, metrics), grads = jax.value_and_grad(lm.lm_loss, has_aux=True)(
        p, batch, cfg, jax.random.key(1))
    assert loss.shape == ()
    assert jnp.isfinite(loss)
    leaves = jax.tree.leaves(grads)
    assert leaves and all(bool(jnp.all(jnp.isfinite(g))) for g in leaves)
    # quantization is ON in the smoke configs: loss near log(vocab) at init
    assert 0.5 * jnp.log(cfg.vocab) < loss < 3.0 * jnp.log(cfg.vocab)


@pytest.mark.parametrize("name", ALL)
def test_smoke_decode_step(name):
    cfg = get_smoke_config(name)
    p = lm.init_lm(jax.random.key(0), cfg)
    batch = make_batch(cfg, b=2, s=16)
    logits, cache = lm.prefill(p, batch, cfg, max_len=32)
    assert logits.shape == (2, cfg.vocab)
    tok = jnp.argmax(logits, -1)[:, None]
    logits2, cache2 = lm.decode_step(p, cache, tok, cfg)
    assert logits2.shape == (2, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits2)))
    assert int(cache2["pos"]) == int(cache["pos"]) + 1


def test_full_configs_match_assignment():
    """Spot-check the exact assigned hyperparameters."""
    q = get_config("qwen2-72b")
    assert (q.n_layers, q.d_model, q.n_heads, q.n_kv_heads, q.d_ff,
            q.vocab, q.qkv_bias) == (80, 8192, 64, 8, 29568, 152064, True)
    y = get_config("yi-34b")
    assert (y.n_layers, y.d_model, y.n_heads, y.n_kv_heads, y.d_ff, y.vocab) \
        == (60, 7168, 56, 8, 20480, 64000)
    m = get_config("moonshot-v1-16b-a3b")
    assert (m.n_experts, m.top_k, m.moe_d_ff, m.vocab) == (64, 6, 1408, 163840)
    l4 = get_config("llama4-scout-17b-a16e")
    assert (l4.n_experts, l4.top_k, l4.vocab, l4.d_model) == (16, 1, 202048, 5120)
    mm = get_config("mamba2-370m")
    assert (mm.n_layers, mm.d_model, mm.ssm_state, mm.vocab) == (48, 1024, 128, 50280)
    z = get_config("zamba2-7b")
    assert (z.n_layers, z.d_model, z.attn_every, z.ssm_state) == (81, 3584, 6, 64)
    s = get_config("seamless-m4t-medium")
    assert (s.enc_layers, s.n_layers, s.d_model, s.vocab) == (12, 12, 1024, 256206)
    g3 = get_config("chatglm3-6b")
    assert (g3.n_kv_heads, g3.rotary_pct, g3.d_ff, g3.vocab) == (2, 0.5, 13696, 65024)
    g4 = get_config("glm4-9b")
    assert (g4.n_layers, g4.vocab) == (40, 151552)
    px = get_config("pixtral-12b")
    assert (px.n_layers, px.d_model, px.frontend) == (40, 5120, "vision")


def test_runnable_shapes_policy():
    """long_500k only for sub-quadratic families (DESIGN.md §4)."""
    for name, cfg in ARCHS.items():
        shapes = {s.name for s in runnable_shapes(cfg)}
        if cfg.family in ("ssm", "hybrid"):
            assert "long_500k" in shapes
        else:
            assert "long_500k" not in shapes
        assert {"train_4k", "prefill_32k", "decode_32k"} <= shapes


def test_param_counts_plausible():
    """n_params() roughly matches the marketing sizes."""
    approx = {
        "qwen2-72b": 72e9, "yi-34b": 34e9, "glm4-9b": 9e9,
        "chatglm3-6b": 6e9, "pixtral-12b": 12e9, "zamba2-7b": 7e9,
        "mamba2-370m": 370e6,
    }
    for name, target in approx.items():
        n = get_config(name).n_params()
        assert 0.5 * target < n < 1.8 * target, (name, n, target)
