"""Checkpoint manager: roundtrip, atomicity, GC, async, resume determinism."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import DataState, make_lm_iterator
from repro.train import CheckpointManager


def _tree():
    return {
        "w": jnp.arange(12.0).reshape(3, 4),
        "nested": {"b": jnp.ones((2,), jnp.bfloat16), "s": jnp.int32(7)},
    }


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    t = _tree()
    mgr.save(5, t)
    assert mgr.latest_step() == 5
    r = mgr.restore(jax.tree.map(lambda x: jnp.zeros_like(x), t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keep_k_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in [1, 2, 3, 4]:
        mgr.save(s, _tree())
    done = sorted(f for f in os.listdir(tmp_path) if f.endswith(".done"))
    assert done == ["step_00000003.done", "step_00000004.done"]


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, _tree(), blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 1


def test_atomicity_ignores_uncommitted(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, _tree())
    # simulate a crashed writer: directory without .done marker
    os.makedirs(tmp_path / "step_00000009")
    assert mgr.latest_step() == 1


def test_data_iterator_state_resumes(tmp_path):
    nxt, state = make_lm_iterator(batch=2, seq=8, vocab=97)
    seen = []
    for _ in range(3):
        b, state = nxt(state)
        seen.append(np.asarray(b["tokens"]))
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(3, state)
    state2 = mgr.restore(state)
    b1, state = nxt(state)
    b2, state2 = nxt(state2)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    # and the stream is not constant
    assert not np.array_equal(seen[0], seen[1])
