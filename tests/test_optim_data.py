"""Optimizers, schedules, data pipeline."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data import cifar_like_batch, make_cifar_iterator, make_lm_iterator
from repro.optim import (
    adamw_init, adamw_update, clip_by_global_norm, cosine_schedule,
    sgdm_init, sgdm_update, step_decay_schedule,
)


def test_sgdm_matches_manual():
    p = {"w": jnp.array([1.0, -2.0])}
    g = {"w": jnp.array([0.5, 0.5])}
    st = sgdm_init(p)
    lr, mom, wd = 0.1, 0.9, 5e-4
    p1, st = sgdm_update(g, st, p, lr, momentum=mom, weight_decay=wd)
    m_ref = np.array([0.5, 0.5]) + wd * np.array([1.0, -2.0])
    np.testing.assert_allclose(np.asarray(p1["w"]),
                               np.array([1.0, -2.0]) - lr * m_ref, rtol=1e-6)
    p2, st = sgdm_update(g, st, p1, lr, momentum=mom, weight_decay=wd)
    g2 = np.array([0.5, 0.5]) + wd * np.asarray(p1["w"])
    m2 = mom * m_ref + g2
    np.testing.assert_allclose(np.asarray(p2["w"]),
                               np.asarray(p1["w"]) - lr * m2, rtol=1e-6)


def test_adamw_first_step_direction():
    p = {"w": jnp.array([1.0, -1.0])}
    g = {"w": jnp.array([0.1, -0.2])}
    st = adamw_init(p)
    p1, st = adamw_update(g, st, p, lr=1e-2, weight_decay=0.0)
    # bias-corrected first step ~= lr * sign(g)
    np.testing.assert_allclose(np.asarray(p1["w"]),
                               np.array([1.0 - 1e-2, -1.0 + 1e-2]), atol=1e-5)
    assert int(st.step) == 1


def test_grad_clip():
    g = {"a": jnp.full((4,), 3.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert np.isclose(float(gn), 6.0)
    assert np.isclose(float(jnp.linalg.norm(clipped["a"])), 1.0)


def test_schedules():
    s = step_decay_schedule(0.1, [80, 120])
    assert np.isclose(float(s(0)), 0.1)
    assert np.isclose(float(s(81)), 0.01)
    assert np.isclose(float(s(121)), 0.001)
    c = cosine_schedule(1e-3, warmup=10, total=110)
    assert float(c(0)) == 0.0
    assert np.isclose(float(c(10)), 1e-3, rtol=1e-3)
    assert float(c(110)) < float(c(50))


def test_cifar_iterator_deterministic():
    nxt, st = make_cifar_iterator(batch=4, hw=16)
    b1, st1 = nxt(st)
    b1b, _ = nxt(st)
    np.testing.assert_array_equal(np.asarray(b1["image"]), np.asarray(b1b["image"]))
    b2, _ = nxt(st1)
    assert not np.array_equal(np.asarray(b1["image"]), np.asarray(b2["image"]))


def test_cifar_classes_are_separable():
    """Class patterns dominate the noise enough to be learnable."""
    b = cifar_like_batch(jax.random.key(0), 256, hw=16, noise=0.5)
    from repro.data.synthetic import _class_pattern

    pats = _class_pattern(10, 16)
    x = b["image"]
    # nearest-pattern classification should beat chance easily
    d = jnp.sum((x[:, None] - pats[None]) ** 2, axis=(2, 3, 4))
    acc = float((jnp.argmin(d, 1) == b["label"]).mean())
    assert acc > 0.9, acc


def test_lm_iterator_learnable_structure():
    nxt, st = make_lm_iterator(batch=4, seq=64, vocab=101)
    b, _ = nxt(st)
    t = np.asarray(b["tokens"])
    # next token is one of 4 deterministic successors of the current token
    succ = (t[:, :-1] * 31 + np.arange(4)[:, None, None] + 7) % 101
    hit = (t[None, :, 1:] == succ).any(0)
    assert hit.mean() == 1.0
