"""Paper CNN zoo: smoke + op-count reproduction (Table I) + quantized
training sanity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FMT_CIFAR, FMT_IMAGENET, QuantConfig
from repro.data import make_cifar_iterator
from repro.models.cnn import CNNConfig, apply_cnn, count_ops, init_cnn
from repro.optim import sgdm_init, sgdm_update

SMOKE = [
    ("resnet20", 16, 0.5),
    ("vgg16", 32, 0.25),  # vgg has 5 maxpools: needs hw >= 32
    ("resnet34", 32, 0.25),
]


@pytest.mark.parametrize("arch,hw,wm", SMOKE)
def test_cnn_smoke(arch, hw, wm):
    cfg = CNNConfig(arch=arch, num_classes=10, width_mult=wm, in_hw=hw)
    qcfg = QuantConfig(fmt=FMT_CIFAR)
    p = init_cnn(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 3, hw, hw))

    def loss(p):
        logits = apply_cnn(p, x, cfg, qcfg, jax.random.key(2))
        assert logits.shape == (2, 10)
        return -jax.nn.log_softmax(logits)[:, 0].mean()

    l, g = jax.value_and_grad(loss)(p)
    assert jnp.isfinite(l)
    assert all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree.leaves(g))


def test_table1_op_counts_resnet18():
    """Paper Table I: ResNet-18 fwd conv ~1.88e9 MACs, FC 5.12e5, EW 7.53e5."""
    ops = count_ops(CNNConfig(arch="resnet18", num_classes=1000, in_hw=224))
    conv = sum(d["c_in"] * d["c_out"] * d["k"] ** 2 * d["h"] * d["w"]
               for k, d in ops if k == "conv")
    fc = sum(d["d_in"] * d["d_out"] * d["rows"] for k, d in ops if k == "fc")
    ew = sum(d["numel"] for k, d in ops if k == "ew_add")
    assert abs(conv - 1.88e9) / 1.88e9 < 0.06
    assert fc == 512_000
    assert abs(ew - 7.53e5) / 7.53e5 < 0.01  # paper rounds to 7.53e5


def test_table1_op_counts_googlenet():
    ops = count_ops(CNNConfig(arch="googlenet", num_classes=1000, in_hw=224))
    conv = sum(d["c_in"] * d["c_out"] * d["k"] ** 2 * d["h"] * d["w"]
               for k, d in ops if k == "conv")
    assert abs(conv - 1.58e9) / 1.58e9 < 0.03


def test_first_and_last_layer_unquantized():
    """Paper Sec. VI-A: stem conv and classifier never quantize."""
    cfg = CNNConfig(arch="resnet20", num_classes=10, width_mult=0.25, in_hw=16)
    from repro.models import nn as nnlib

    with nnlib.OpTrace() as tr:
        p = jax.eval_shape(lambda k: init_cnn(k, cfg), jax.random.key(0))
        p = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), p)
        jax.eval_shape(
            lambda x: apply_cnn(p, x, cfg, QuantConfig(fmt=FMT_CIFAR),
                                jax.random.key(1)),
            jax.ShapeDtypeStruct((1, 3, 16, 16), jnp.float32),
        )
    convs = [d for k, d in tr.ops if k == "conv"]
    fcs = [d for k, d in tr.ops if k == "fc"]
    assert convs[0]["quantized"] is False  # stem
    assert all(c["quantized"] for c in convs[1:])
    assert fcs[-1]["quantized"] is False  # classifier


def test_quantized_cnn_training_decreases_loss():
    cfg = CNNConfig(arch="resnet20", num_classes=10, width_mult=0.25, in_hw=16)
    qcfg = QuantConfig(fmt=FMT_IMAGENET)
    params = init_cnn(jax.random.key(0), cfg)
    opt = sgdm_init(params)
    nxt, ds = make_cifar_iterator(batch=16, hw=16)

    @jax.jit
    def step(params, opt, batch, i):
        def loss_fn(p):
            logits = apply_cnn(p, batch["image"], cfg, qcfg,
                               jax.random.fold_in(jax.random.key(9), i))
            ll = jax.nn.log_softmax(logits)
            return -jnp.take_along_axis(ll, batch["label"][:, None], 1).mean()

        l, g = jax.value_and_grad(loss_fn)(params)
        params, opt = sgdm_update(g, opt, params, lr=0.05)
        return params, opt, l

    losses = []
    for i in range(12):
        batch, ds = nxt(ds)
        params, opt, l = step(params, opt, batch, i)
        losses.append(float(l))
    assert losses[-1] < losses[0] - 0.4, losses
