"""Deterministic stand-in for `hypothesis` when it isn't installed.

CI installs the real hypothesis (see pyproject `[dev]`); environments
without it (e.g. a bare container) fall back to this shim so the property
tests still run — each ``@given`` test executes a fixed number of
deterministic pseudo-random examples instead of being skipped.

Only the surface used by this test suite is implemented: ``given``,
``settings(max_examples=..., deadline=...)``, ``strategies.integers`` and
``strategies.sampled_from``.
"""
import random

_MAX_EXAMPLES_CAP = 10  # keep the fallback fast; real hypothesis digs deeper


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng):
        return self._draw(rng)


class _Strategies:
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda rng: elements[rng.randrange(len(elements))])

    @staticmethod
    def floats(min_value=0.0, max_value=1.0, **_):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))


strategies = st = _Strategies()


def settings(max_examples=_MAX_EXAMPLES_CAP, **_ignored):
    def deco(fn):
        fn._max_examples = max_examples
        return fn

    return deco


def given(*strats):
    def deco(fn):
        def run():
            n = min(getattr(fn, "_max_examples", _MAX_EXAMPLES_CAP),
                    _MAX_EXAMPLES_CAP)
            rng = random.Random(0)
            for _ in range(n):
                fn(*(s.draw(rng) for s in strats))

        # plain zero-arg wrapper on purpose: pytest must not see the wrapped
        # signature, or it would treat the strategy params as fixtures
        run.__name__ = fn.__name__
        run.__doc__ = fn.__doc__
        return run

    return deco
