"""ServeEngine: batched generation through the public API."""
import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import lm
from repro.serve import ServeEngine


def test_generate_greedy_deterministic():
    cfg = get_smoke_config("chatglm3-6b")
    params = lm.init_lm(jax.random.key(0), cfg)
    eng = ServeEngine(cfg, params, max_len=48)
    prompts = jax.random.randint(jax.random.key(1), (2, 8), 0, cfg.vocab)
    out1 = eng.generate({"tokens": prompts}, max_new_tokens=6)
    out2 = eng.generate({"tokens": prompts}, max_new_tokens=6)
    assert out1.shape == (2, 6)
    assert bool((out1 == out2).all())
    assert bool((out1 >= 0).all()) and bool((out1 < cfg.vocab).all())


def test_generate_sampled_varies():
    cfg = get_smoke_config("mamba2-370m")
    params = lm.init_lm(jax.random.key(0), cfg)
    eng = ServeEngine(cfg, params, max_len=48)
    prompts = jax.random.randint(jax.random.key(1), (2, 8), 0, cfg.vocab)
    a = eng.generate({"tokens": prompts}, 6, temperature=1.0,
                     key=jax.random.key(2))
    b = eng.generate({"tokens": prompts}, 6, temperature=1.0,
                     key=jax.random.key(3))
    assert a.shape == (2, 6)
    assert bool((a != b).any())
