"""Shape-keyed autotuner: cache round-trip, hit short-circuit, corrupted
/stale fallback, seed-cache legality, resolution precedence, interpret
switch."""
import json

import pytest

from repro.core.formats import FMT_CIFAR, FMT_IMAGENET
from repro.kernels import runtime
from repro.kernels.autotune import (
    CACHE_SCHEMA_VERSION,
    SEED_CACHE_PATH,
    BlockConfig,
    TuneCache,
    TuneSpec,
    check_cache,
    default_block_config,
    gemm_candidates,
    registry_specs,
    resolve_block_config,
    tune,
    verify_config,
)

SPEC = TuneSpec("gemm", (64, 64, 64), FMT_CIFAR, k_block=32)
QSPEC = TuneSpec("quantize", (64, 64), FMT_CIFAR, k_block=32)


def _fake_timer(times=None, calls=None):
    """Timer stub: records calls, serves canned (or constant) timings."""
    def timer(spec, config):
        if calls is not None:
            calls.append((spec.key(), config))
        if times:
            return times.pop(0)
        return 100.0
    return timer


# ---------------------------------------------------------------------------
# cache round-trip
# ---------------------------------------------------------------------------
def test_cache_round_trip_identical_blockconfig(tmp_path):
    path = tmp_path / "tune.json"
    cache = TuneCache(path)
    cfg = BlockConfig(64, 32, 16, "c")
    cache.put(SPEC, cfg, 123.456, timed=7)
    cache.save()

    loaded = TuneCache.load(path)
    assert not loaded.load_warnings
    assert loaded.get(SPEC.key()) == cfg  # identical, not just equal fields
    ent = loaded.entries[SPEC.key()]
    assert ent["us"] == 123.46 and ent["candidates_timed"] == 7
    assert TuneSpec.from_json(ent) == SPEC


def test_blockconfig_json_round_trip():
    cfg = BlockConfig(128, 64, 256, "none")
    assert BlockConfig.from_json(cfg.to_json()) == cfg


# ---------------------------------------------------------------------------
# tuning: short-circuit and verifier pruning
# ---------------------------------------------------------------------------
def test_cache_hit_short_circuits_timing(tmp_path):
    cache = TuneCache(tmp_path / "tune.json")
    calls = []
    winner = tune(SPEC, cache, timer=_fake_timer(calls=calls))
    assert calls, "first tune must time candidates"
    n_first = len(calls)
    again = tune(SPEC, cache, timer=_fake_timer(calls=calls))
    assert again == winner
    assert len(calls) == n_first, "cache hit must not re-time"


def test_tune_times_only_verified_candidates(tmp_path):
    cache = TuneCache(tmp_path / "tune.json")
    calls = []
    tune(SPEC, cache, timer=_fake_timer(calls=calls))
    assert len(calls) == len(
        [c for c in gemm_candidates(SPEC) if verify_config(SPEC, c).ok])


def test_tune_persists_winner_by_min_time(tmp_path):
    cache = TuneCache(tmp_path / "tune.json")
    n = len(gemm_candidates(SPEC))
    times = [float(100 - i) for i in range(n)]  # last candidate fastest
    winner = tune(SPEC, cache, timer=_fake_timer(times=list(times)))
    assert cache.get(SPEC.key()) == winner
    legal = [c for c in gemm_candidates(SPEC) if verify_config(SPEC, c).ok]
    assert winner == legal[-1]


# ---------------------------------------------------------------------------
# corrupted / stale caches degrade to defaults, never crash
# ---------------------------------------------------------------------------
def test_corrupted_cache_falls_back_to_default(tmp_path):
    path = tmp_path / "tune.json"
    path.write_text("{not json at all")
    cache = TuneCache.load(path)
    assert len(cache) == 0 and cache.load_warnings
    resolved = resolve_block_config(
        "gemm", SPEC.shape, SPEC.fmt, k_block=32, cache=cache)
    assert resolved == default_block_config(
        shape=SPEC.shape, fmt=SPEC.fmt, k_block=32)


def test_stale_schema_version_ignored(tmp_path):
    path = tmp_path / "tune.json"
    path.write_text(json.dumps({
        "version": CACHE_SCHEMA_VERSION + 1,
        "entries": {SPEC.key(): {"config": {
            "block_m": 8, "block_n": 8, "k_block": 8, "grouping": "nc"}}},
    }))
    cache = TuneCache.load(path)
    assert len(cache) == 0
    assert any("schema" in w for w in cache.load_warnings)


def test_malformed_entry_dropped_others_kept(tmp_path):
    path = tmp_path / "tune.json"
    good = BlockConfig(64, 64, 32, "nc")
    payload = {
        "version": CACHE_SCHEMA_VERSION,
        "entries": {
            "bad:key": {"config": {"block_m": "what"}},
            SPEC.key(): {**SPEC.to_json(), "config": good.to_json(),
                         "us": 1.0, "candidates_timed": 1},
        },
    }
    path.write_text(json.dumps(payload))
    cache = TuneCache.load(path)
    assert cache.get(SPEC.key()) == good
    assert "bad:key" not in cache.entries and cache.load_warnings


# ---------------------------------------------------------------------------
# resolution precedence: explicit > cache > default
# ---------------------------------------------------------------------------
def test_resolution_precedence(tmp_path):
    cache = TuneCache(tmp_path / "tune.json")
    cached = BlockConfig(32, 64, 64, "nc")
    cache.put(SPEC, cached, 1.0)
    # cache hit wins over default
    assert resolve_block_config(
        "gemm", SPEC.shape, SPEC.fmt, cache=cache) == cached
    # explicit fields win over the cached winner
    r = resolve_block_config(
        "gemm", SPEC.shape, SPEC.fmt, k_block=32, block_m=128, cache=cache)
    assert (r.block_m, r.block_n, r.k_block) == (128, 64, 32)
    # no hit -> proven-legal default at the caller's k_block
    r = resolve_block_config(
        "gemm", (8, 32, 8), SPEC.fmt, k_block=16, cache=cache)
    assert r == BlockConfig(128, 128, 16, "nc")


# ---------------------------------------------------------------------------
# committed seed cache: coverage + winners still prove legal
# ---------------------------------------------------------------------------
def test_seed_cache_exists_and_checks_clean():
    assert SEED_CACHE_PATH.exists(), (
        "committed seed cache missing; run "
        "python -m repro.kernels.autotune --tune --cache "
        "src/repro/kernels/tuned/kernel_tune.json")
    cache = TuneCache.load(SEED_CACHE_PATH)
    assert not cache.load_warnings
    report = check_cache(cache)
    assert report["ok"], report["failures"]
    # every registry tuning spec has a seeded winner
    for spec in registry_specs():
        assert cache.get(spec.key()) is not None, spec.key()


def test_check_cache_flags_missing_spec(tmp_path):
    report = check_cache(TuneCache(tmp_path / "empty.json"))
    assert not report["ok"]
    assert any("no tuning-cache entry" in f for f in report["failures"])


def test_check_cache_flags_illegal_winner(tmp_path):
    cache = TuneCache(tmp_path / "tune.json")
    # k_block=2048 at <2,4> overflows the 24-bit accumulator budget
    bad_spec = TuneSpec("gemm", (8, 2048, 8), FMT_IMAGENET, k_block=2048)
    cache.put(bad_spec, BlockConfig(8, 8, 2048, "nc"), 1.0)
    report = check_cache(cache, specs=[bad_spec])
    assert not report["ok"]
    assert any("no longer verifies" in f for f in report["failures"])


def test_quantize_spec_verifies():
    assert verify_config(QSPEC, BlockConfig(64, 128, 32, "nc")).ok


# ---------------------------------------------------------------------------
# process-wide interpret switch (REPRO_PALLAS_INTERPRET)
# ---------------------------------------------------------------------------
def test_interpret_env_switch(monkeypatch):
    monkeypatch.delenv(runtime.INTERPRET_ENV_VAR, raising=False)
    auto = runtime.default_interpret()
    assert isinstance(auto, bool)  # platform auto (True on CPU CI)
    monkeypatch.setenv(runtime.INTERPRET_ENV_VAR, "0")
    assert runtime.default_interpret() is False
    monkeypatch.setenv(runtime.INTERPRET_ENV_VAR, "off")
    assert runtime.default_interpret() is False
    monkeypatch.setenv(runtime.INTERPRET_ENV_VAR, "1")
    assert runtime.default_interpret() is True
    # explicit argument always wins
    assert runtime.resolve_interpret(False) is False
    monkeypatch.setenv(runtime.INTERPRET_ENV_VAR, "0")
    assert runtime.resolve_interpret(True) is True


def test_interpret_arg_defers_to_env(monkeypatch):
    monkeypatch.setenv(runtime.INTERPRET_ENV_VAR, "no")
    assert runtime.resolve_interpret(None) is False
    monkeypatch.setenv(runtime.INTERPRET_ENV_VAR, "yes")
    assert runtime.resolve_interpret(None) is True
