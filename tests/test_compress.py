"""MLS gradient compression: codec bounds + the cross-pod ring all-reduce
(exercised in a subprocess with 4 forced host devices)."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import FMT_IMAGENET
from repro.parallel.compress import compress, decompress


def test_codec_roundtrip_error_bound():
    g = jax.random.normal(jax.random.key(0), (1000,)) * 1e-3
    codes, sg, st = compress(g, FMT_IMAGENET)
    r = decompress(codes, sg, st, g.shape, FMT_IMAGENET)
    are = float(jnp.abs(r - g).mean() / jnp.abs(g).mean())
    assert are < 0.05, are
    # wire payload: 1 B/elem + 4 B/group + 4 B
    wire = codes.size + sg.size * 4 + 4
    assert wire < 0.3 * g.size * 4  # > 3.3x smaller than fp32


def test_codec_unbiased_with_key():
    g = jnp.full((20000,), 3.33e-4)
    g = jnp.concatenate([g, jnp.array([1e-3])])  # scale anchor
    codes, sg, st = compress(g, FMT_IMAGENET, key=jax.random.key(1))
    r = decompress(codes, sg, st, g.shape, FMT_IMAGENET)
    assert abs(float(r[:-1].mean()) - 3.33e-4) < 5e-6


_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from functools import partial
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import make_mesh
from repro.parallel.compress import crosspod_allreduce_mean

shard_map = getattr(jax, "shard_map", None)
if shard_map is None:  # jax < 0.6 keeps it in experimental
    from jax.experimental.shard_map import shard_map

mesh = make_mesh((2, 2), ("pod", "data"))
g = jax.random.normal(jax.random.key(0), (4, 256))

@partial(shard_map, mesh=mesh, in_specs=P("pod", None),
         out_specs=P("pod", None))
def f(x):
    return crosspod_allreduce_mean(x, "pod")[None] if x.ndim == 1 else \
        crosspod_allreduce_mean(x[0], "pod")[None]

out = f(g)
ref = jnp.stack([g[:2].mean(0), g[2:].mean(0)])  # pods hold rows (0,1),(2,3)
# shard_map over pod: each pod sees rows; our in_spec slices rows 2-at-a-time
# -> x[0] per pod is row 0 / row 2; mean over pods of those rows:
ref = (g[0] + g[2]) / 2
err = float(jnp.abs(out - ref).max() / jnp.abs(ref).max())
print("ERR", err)
assert err < 0.03, err
print("OK")
"""


def test_crosspod_ring_allreduce_subprocess():
    r = subprocess.run(
        [sys.executable, "-c", _SUBPROC], capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo", timeout=600,
    )
    assert "OK" in r.stdout, (r.stdout, r.stderr)
