"""Sharding-spec inference and the HLO analysis used by the roofline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.hlo_analysis import analyze_hlo, split_computations
from repro.parallel import DEFAULT_RULES
from repro.parallel.specs import logical_axes_for, spec_for


class FakeMesh:
    axis_names = ("data", "model")

    class devices:
        shape = (16, 16)
        size = 256


def test_logical_axes_rules():
    assert logical_axes_for("['emb']", 2) == ("vocab", "fsdp")
    assert logical_axes_for("['layers']['attn']['wq']['w']", 3) == \
        ("stage", "fsdp", "heads")
    assert logical_axes_for("['layers']['attn']['wq']['b']", 2) == \
        ("stage", "heads")
    assert logical_axes_for("['layers']['moe']['w_down']", 4) == \
        ("stage", "expert", None, "fsdp")
    assert logical_axes_for("['layers']['mlp']['w_down']['w']", 3) == \
        ("stage", "mlp", "fsdp")
    assert logical_axes_for("['final_norm']['gamma']", 1) == (None,)


def test_spec_divisibility_fallback():
    mesh = FakeMesh()
    # kv_heads = 8 not divisible by model=16 -> replicated on that dim
    s = spec_for("['layers']['attn']['wk']['w']", (80, 8192, 1024), mesh,
                 DEFAULT_RULES)
    assert s == P(None, "data", "model")
    s2 = spec_for("['layers']['attn']['wk']['w']", (80, 8191, 1024), mesh,
                  DEFAULT_RULES)
    assert s2 == P(None, None, "model")  # 8191 not divisible by 16


CANNED_HLO = """
HloModule test

%cond.1 (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%body.1 (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %x = f32[8,8] get-tuple-element(%p), index=1
  %w = f32[8,8] get-tuple-element(%p), index=1
  %dot.1 = f32[8,8]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8]{1,0} all-reduce(%dot.1), replica_groups=[16,16]<=[256], to_apply=%add
  %i = s32[] get-tuple-element(%p), index=0
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]) tuple(%ni, %ar)
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8] parameter(0)
  %init = (s32[], f32[8,8]) tuple(s32[] constant(0), %a)
  %w1 = (s32[], f32[8,8]) while(%init), condition=%cond.1, body=%body.1
  %ag = f32[128,8]{1,0} all-gather(%a), replica_groups=[16,16]<=[256], dimensions={0}
  ROOT %out = f32[8,8] get-tuple-element(%w1), index=1
}
"""


def test_hlo_parser_canned():
    res = analyze_hlo(CANNED_HLO)
    # dot: 2*8*8*8 = 1024 flops, x12 loop trips
    assert res["dot_flops"] == 12 * 1024
    # all-reduce in loop: 2 * 256B * 15/16 * 12; all-gather once: 4096B*15/16
    ar = 2 * (8 * 8 * 4) * 15 / 16 * 12
    ag = (128 * 8 * 4) * 15 / 16
    assert np.isclose(res["coll_breakdown"]["all-reduce"], ar)
    assert np.isclose(res["coll_breakdown"]["all-gather"], ag)


def test_hlo_parser_on_real_compiled_program():
    """Single-device compiled scan: dot flops must be trip-multiplied."""
    def f(x, w):
        def body(c, wl):
            return jnp.tanh(c @ wl), None
        y, _ = jax.lax.scan(body, x, w)
        return y.sum()

    txt = (
        jax.jit(f)
        .lower(jax.ShapeDtypeStruct((16, 32), jnp.float32),
               jax.ShapeDtypeStruct((5, 32, 32), jnp.float32))
        .compile()
        .as_text()
    )
    res = analyze_hlo(txt)
    assert res["dot_flops"] == 5 * 2 * 16 * 32 * 32, res["dot_flops"]


def test_known_trip_count_preferred():
    comps = split_computations(CANNED_HLO)
    assert {"cond.1", "body.1", "main"} <= set(comps)
