"""Quantized-domain coverage auditor: classification of dots/convs,
loop/grid multipliers, and the CI gate (including the planted-fp32
negative control)."""
import json

import jax
import jax.numpy as jnp

from repro.analysis.audit import _BASELINE, apply_gate
from repro.analysis.coverage import coverage_of_jaxpr, trace_coverage
from repro.analysis.graphs import cifar_train_graph
from repro.core import FMT_IMAGENET, QuantConfig
from repro.kernels.lowbit_conv import lowbit_conv_fused, lowbit_matmul_qd
from repro.core.lowbit import lowbit_matmul


def _qcfg(backend):
    return QuantConfig(fmt=FMT_IMAGENET, backend=backend, stochastic=False,
                       k_block=32, pallas_interpret=True)


def test_pallas_matmul_grad_fully_quantized():
    cfg = _qcfg("pallas")

    def loss(x, w):
        return lowbit_matmul_qd(x, w, None, cfg).sum()

    rep = trace_coverage(
        jax.grad(loss, argnums=(0, 1)),
        jax.ShapeDtypeStruct((64, 96), jnp.float32),
        jax.ShapeDtypeStruct((96, 128), jnp.float32),
    )
    assert rep.quantized_macs > 0
    assert rep.full_precision_macs == 0
    assert rep.quantized_fraction == 1.0
    # all three training GEMMs (fwd, dgrad, wgrad) visible
    assert sum(1 for s in rep.sites if s.klass == "quantized") == 3


def test_fake_quant_backend_is_full_precision():
    cfg = QuantConfig(fmt=FMT_IMAGENET, stochastic=False)  # fake_quant

    def loss(x, w):
        return lowbit_matmul(x, w, None, cfg).sum()

    rep = trace_coverage(
        jax.grad(loss, argnums=(0, 1)),
        jax.ShapeDtypeStruct((64, 96), jnp.float32),
        jax.ShapeDtypeStruct((96, 128), jnp.float32),
    )
    assert rep.quantized_macs == 0
    assert rep.full_precision_macs > 0
    assert rep.quantized_fraction == 0.0


def test_scan_length_multiplies_macs():
    def f(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), None

        h, _ = jax.lax.scan(body, x, None, length=5)
        return h

    rep = trace_coverage(
        f,
        jax.ShapeDtypeStruct((8, 16), jnp.float32),
        jax.ShapeDtypeStruct((16, 16), jnp.float32),
    )
    assert rep.full_precision_macs == 5 * 8 * 16 * 16
    assert any("scan[5]" in s.path for s in rep.sites)


def test_im2col_patch_convs_are_data_movement():
    cfg = _qcfg("pallas")

    def loss(x, w):
        return lowbit_conv_fused(x, w, None, (1, 1), "SAME", cfg).sum()

    rep = trace_coverage(
        jax.grad(loss, argnums=(0, 1)),
        jax.ShapeDtypeStruct((2, 8, 8, 8), jnp.float32),
        jax.ShapeDtypeStruct((16, 8, 3, 3), jnp.float32),
    )
    convs = [s for s in rep.sites if s.kind == "conv"]
    assert convs, "expected im2col patch-extraction convs in the trace"
    assert all(s.klass == "data_movement" for s in convs)
    assert rep.quantized_fraction == 1.0  # GEMMs quantized, convs excluded


def _gate_report(cov):
    return {
        "graphs": {
            "train:resnet20": {
                "coverage": cov.to_json(),
                "lint": {"ok": True, "errors": [], "warnings": []},
            }
        }
    }


def test_resnet20_train_step_meets_coverage_gate():
    cov = coverage_of_jaxpr(cifar_train_graph(backend="pallas").jaxpr())
    assert cov.quantized_fraction >= 0.99, cov.to_json()
    # stem conv + classifier are unquantized by design, so fp32 > 0
    assert cov.full_precision_macs > 0
    assert cov.data_movement_macs > 0  # im2col patch gathers reported apart
    with open(_BASELINE) as f:
        baseline = json.load(f)
    assert apply_gate(_gate_report(cov), baseline) == []


def test_gate_catches_planted_fp32_dot():
    g = cifar_train_graph(backend="pallas", sabotage=True)
    cov = coverage_of_jaxpr(g.jaxpr())
    assert cov.quantized_fraction < 0.99
    with open(_BASELINE) as f:
        baseline = json.load(f)
    failures = apply_gate(_gate_report(cov), baseline)
    assert failures and "train:resnet20" in failures[0]
    # the report names the planted dot as the largest fp32 site
    assert "'kind': 'dot'" in failures[0]


def test_hlo_parser_compat_shim():
    from repro.analysis import hlo_parser
    from repro.launch import hlo_analysis

    assert hlo_analysis.analyze_hlo is hlo_parser.analyze_hlo
    res = hlo_parser.analyze_hlo("")
    assert "dot_flops_by_dtype" in res and "coll_breakdown" in res
