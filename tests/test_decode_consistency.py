"""Incremental decode must match the teacher-forced full forward."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.models import lm
from repro.models.lm import (
    _dense_scan, _encoder_apply, _hybrid_apply, _ssm_scan, _xdec_scan,
    logits_fn,
)
from repro.models.transformer import norm_apply

CASES = ["qwen2-72b", "chatglm3-6b", "mamba2-370m", "zamba2-7b",
         "seamless-m4t-medium", "moonshot-v1-16b-a3b"]


def full_logits(p, batch, cfg):
    x = lm.embed(p, batch, cfg)
    if cfg.family in ("dense", "moe"):
        xf, _, _ = _dense_scan(p, x, cfg, None, None, layer_kind=cfg.family)
    elif cfg.family == "ssm":
        xf, _, _ = _ssm_scan(p, x, cfg, None, None)
    elif cfg.family == "hybrid":
        xf, _, _, _ = _hybrid_apply(p, x, cfg, None, None)
    else:
        mem = _encoder_apply(p, batch, cfg, None, None)
        xf, _ = _xdec_scan(p, x, cfg, None, None, mem)
    return logits_fn(p, norm_apply(cfg, p["final_norm"], xf), cfg)


@pytest.mark.parametrize("name", CASES)
def test_decode_matches_forward(name):
    cfg = dataclasses.replace(get_smoke_config(name), quant=False)
    if cfg.family == "moe":
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)  # no drops
    p = lm.init_lm(jax.random.key(0), cfg)
    b, s = 2, 16
    toks = jax.random.randint(jax.random.key(3), (b, s), 0, cfg.vocab)
    batch = {"tokens": toks}
    if cfg.family == "encdec":
        batch["src_emb"] = jax.random.normal(jax.random.key(1),
                                             (b, s, cfg.frontend_dim))
    ref = full_logits(p, batch, cfg)
    pre = {k: (v[:, :8] if k == "tokens" else v) for k, v in batch.items()}
    logits, cache = lm.prefill(p, pre, cfg, max_len=32)
    errs = [float(jnp.abs(logits - ref[:, 7]).max())]
    for i in range(8, s):
        logits, cache = lm.decode_step(p, cache, toks[:, i:i + 1], cfg)
        errs.append(float(jnp.abs(logits - ref[:, i]).max()))
    assert max(errs) < 5e-4, errs


def test_hybrid_ring_buffer_window():
    """Zamba2 long-context mode: ring-buffer attention == windowed attention
    computed directly over the full sequence."""
    cfg = dataclasses.replace(get_smoke_config("zamba2-7b"), quant=False,
                              window=8)
    p = lm.init_lm(jax.random.key(0), cfg)
    b, s = 1, 24
    toks = jax.random.randint(jax.random.key(3), (b, s), 0, cfg.vocab)
    # reference: full forward with sliding window via _hybrid_apply
    x = lm.embed(p, {"tokens": toks}, cfg)
    xf, _, _, _ = _hybrid_apply(p, x, cfg, None, None, window=cfg.window)
    ref = logits_fn(p, norm_apply(cfg, p["final_norm"], xf), cfg)
    # decode token by token through the ring buffer
    cache = lm.init_cache(cfg, b, max_len=cfg.window)
    errs = []
    for i in range(s):
        logits, cache = lm.decode_step(p, cache, toks[:, i:i + 1], cfg)
        errs.append(float(jnp.abs(logits - ref[:, i]).max()))
    assert max(errs) < 5e-4, errs
