"""Numerics legality lint + property-based checks of the ⟨E,M⟩ product /
accumulation bit math (hypothesis, falling back to the local shim)."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    from _hypothesis_fallback import given, settings, st

from repro.analysis.lint import (
    check_format_pair,
    lint_quant_config,
    lint_shipped_presets,
)
from repro.core import FMT_CIFAR, FMT_IMAGENET, QuantConfig
from repro.core.formats import EMFormat, accumulation_bits


# ---------------------------------------------------------------------------
# lint on shipped / explicit configs
# ---------------------------------------------------------------------------
def test_shipped_presets_all_legal():
    results = lint_shipped_presets()
    assert len(results) == 10
    bad = {a: r.errors for a, r in results.items() if not r.ok}
    assert not bad, bad


def test_paper_formats_legal_at_paper_depth():
    for fmt in (FMT_CIFAR, FMT_IMAGENET):
        assert check_format_pair(fmt, 128) == []
    assert lint_quant_config(
        QuantConfig(fmt=FMT_CIFAR, backend="pallas", pallas_interpret=True)
    ).ok


def test_accumulator_invariant_rejected_at_construction():
    # <2,4>: 14 product bits + log2(1024) = 24 >= 24 -> not exact in fp32
    with pytest.raises(ValueError, match="no longer exact"):
        QuantConfig(fmt=FMT_IMAGENET, k_block=1024)
    # boundary: 512-deep groups still have 23 bits -> legal
    QuantConfig(fmt=FMT_IMAGENET, k_block=512)
    assert check_format_pair(FMT_IMAGENET, 1024) != []


def test_invalid_grouping_rejected():
    with pytest.raises(ValueError, match="grouping"):
        QuantConfig(grouping="rowwise")


def test_pallas_kblock_tiling_rules():
    res = lint_quant_config(
        QuantConfig(fmt=FMT_IMAGENET, backend="pallas", k_block=48)
    )
    assert not res.ok and "power-of-two" in res.errors[0]
    res = lint_quant_config(
        QuantConfig(fmt=FMT_IMAGENET, backend="pallas", k_block=32)
    )
    assert res.ok
    assert any("128-wide TPU lane" in w for w in res.warnings)


def test_group_scale_format_rules():
    res = lint_quant_config(QuantConfig(gs_fmt=EMFormat(8, 3)))
    assert not res.ok and "Mg=3" in res.errors[0]
    res = lint_quant_config(QuantConfig(gs_fmt=EMFormat(2, 1)))
    assert res.ok and any("underflow" in w for w in res.warnings)


def test_oversized_element_format_rejected():
    # <3,5> needs 9 storage bits -> cannot pack into uint8 codes
    errs = check_format_pair(EMFormat(3, 5), 16)
    assert any("uint8" in e for e in errs)


# ---------------------------------------------------------------------------
# property-based: product/accumulation bit bounds vs brute force
# ---------------------------------------------------------------------------
def _max_fraction(fmt: EMFormat) -> int:
    """Largest |integer fraction| any code decodes to (mirrors the Pallas
    kernel's decode: base << shift)."""
    best = 0
    top = 2**fmt.e - 1
    for exp in range(2**fmt.e):
        for man in range(2**fmt.m):
            base = man if exp == 0 else 2**fmt.m + man
            shift = 0 if exp == 0 else top - exp
            best = max(best, base << shift)
    return best


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 3), st.integers(1, 7),
       st.sampled_from([1, 2, 8, 32, 128, 512, 2048]))
def test_product_bits_bounds_brute_force(e, m, k_block):
    fmt = EMFormat(e, m)
    fmax = _max_fraction(fmt)
    # product_bits is a tight power-of-two envelope of the worst product
    assert fmax * fmax < 2**fmt.product_bits
    assert fmax * fmax >= 2 ** (fmt.product_bits - 2)
    # whenever the invariant says "exact", a worst-case group sum really
    # stays below 2^24 and fp32 accumulation is bit-exact
    if accumulation_bits(fmt, k_block) < 24:
        worst_sum = k_block * fmax * fmax
        assert worst_sum < 2**24
        acc = np.float32(0.0)
        p = np.float32(fmax * fmax)
        for _ in range(k_block):
            acc += p
        assert int(acc) == worst_sum


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 3), st.integers(0, 6))
def test_max_value_matches_grid(e, m):
    if e == 0 and m == 0:
        return
    fmt = EMFormat(e, m)
    grid = fmt.grid()
    assert grid[-1] == pytest.approx(fmt.max_value)
    assert np.all(grid <= fmt.max_value)
    assert fmt.element_bits == 1 + e + m


def test_pallas_grouping_is_first_class_no_warning():
    """Non-"nc" groupings are honored by the Pallas kernels now; the old
    "silently ignores grouping" warning must be gone."""
    for grouping in ("c", "n", "none"):
        res = lint_quant_config(QuantConfig(
            fmt=FMT_IMAGENET, backend="pallas", grouping=grouping,
            k_block=128))
        assert res.ok
        assert not any("grouping" in w for w in res.warnings), res.warnings
