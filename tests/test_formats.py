"""Format-level tests: <E,M> math, Alg. 2 quantization, paper §V-C analysis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:  # CI installs hypothesis (pyproject [dev]); bare containers may lack it
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # deterministic fixed-example fallback
    from _hypothesis_fallback import given, settings, st

from repro.core import (
    EMFormat, FMT_CIFAR, FMT_IMAGENET, GS_FMT_DEFAULT, GroupSpec,
    average_relative_error, mls_quantize, pack_elements, unpack_elements,
)
from repro.core.formats import exponent_fraction
from repro.core.quantize import quantize_elements, quantize_group_scale


def test_accum_bitwidth_analysis():
    """Paper §V-C: <2,4> products are 14-bit => integer accumulators."""
    assert FMT_IMAGENET.product_bits == 14
    assert FMT_CIFAR.product_bits == 2 * 1 + 2 ** (2 + 1) - 2  # 8
    # FP8 (E=5) products are 2M+2^6-2 = 68-bit-range -> float accum needed
    assert EMFormat(e=5, m=2).product_bits > 32


def test_grid_structure():
    fmt = FMT_IMAGENET
    g = fmt.grid()
    assert g[0] == 0.0
    assert np.isclose(g[-1], fmt.max_value)
    assert np.all(np.diff(g) > 0)
    # gradual underflow: spacing below min_normal equals spacing just above
    below = g[(g > 0) & (g < fmt.min_normal)]
    assert np.allclose(np.diff(below), fmt.min_subnormal)


def test_exponent_fraction_exact():
    xs = jnp.array([1.0, 1.5, 0.75, 2.0, 3.1415, 1e-20, 0.0, 1e20])
    e, f = exponent_fraction(xs)
    e, f = np.asarray(e), np.asarray(f)
    for i, x in enumerate(np.asarray(xs)):
        if x == 0 or x < 2**-126:
            assert f[i] == 0.0
        else:
            assert np.isclose(f[i] * 2.0 ** e[i], x, rtol=0)
            assert 1.0 <= f[i] < 2.0


@pytest.mark.parametrize("fmt", [FMT_CIFAR, FMT_IMAGENET, EMFormat(2, 2),
                                 EMFormat(1, 3), EMFormat(3, 2)])
def test_grid_idempotent(fmt):
    g = jnp.array(fmt.grid())
    xb, es, mn = quantize_elements(g, fmt, None)
    np.testing.assert_array_equal(np.asarray(xb), np.asarray(g))
    # storage fields reconstruct the value
    top = 2**fmt.e - 1
    es, mn = np.asarray(es), np.asarray(mn)
    rec = np.where(
        es == 0,
        mn / 2**fmt.m * 2.0 ** fmt.e_min,
        (1 + mn / 2**fmt.m) * 2.0 ** (-es.astype(float)),
    )
    np.testing.assert_allclose(rec, np.asarray(g))


@given(st.integers(1, 3), st.integers(1, 4), st.integers(0, 2**32 - 1))
@settings(max_examples=30, deadline=None)
def test_quantize_error_bound(e, m, seed):
    """Nearest rounding error <= half a grid step at the value's scale."""
    fmt = EMFormat(e=e, m=m)
    x = jax.random.uniform(jax.random.key(seed), (64,), minval=0.0, maxval=1.0)
    xb, _, _ = quantize_elements(x, fmt, None)
    xb, x = np.asarray(xb, np.float64), np.asarray(x, np.float64)
    # step at magnitude: 2^(clip(floor(log2 x), e_min, -1) - m)
    with np.errstate(divide="ignore"):
        ee = np.clip(np.floor(np.log2(np.maximum(x, 1e-30))), fmt.e_min, -1)
    step = 2.0 ** (ee - fmt.m)
    sat = x > fmt.max_value  # top-of-grid saturation clips harder
    assert np.all(np.abs(xb - x)[~sat] <= step[~sat] / 2 + 1e-9)
    assert np.all(xb <= fmt.max_value + 1e-12)


@given(st.integers(0, 2**32 - 1))
@settings(max_examples=20, deadline=None)
def test_group_scale_ceil_property(seed):
    """Quantized group scales always >= the true ratio (so elements <= 1)."""
    r = jax.random.uniform(jax.random.key(seed), (32,), minval=0.0, maxval=1.0)
    sg, eg, mg = quantize_group_scale(r, GS_FMT_DEFAULT)
    sg = np.asarray(sg)
    assert np.all(sg >= np.asarray(r) - 1e-7)
    # and within one mantissa step above (no gratuitous over-scaling)
    nz = np.asarray(r) > 2**-100
    assert np.all(sg[nz] <= np.asarray(r)[nz] * (1 + 2.0**-GS_FMT_DEFAULT.m) + 1e-7)


@given(st.integers(0, 2**32 - 1), st.sampled_from(["nc", "per_tensor"]))
@settings(max_examples=15, deadline=None)
def test_mls_roundtrip_bound(seed, grouping):
    key = jax.random.key(seed)
    x = jax.random.normal(key, (8, 16, 3, 3)) * jax.random.uniform(
        jax.random.fold_in(key, 1), (8, 16, 1, 1), minval=0.01, maxval=10.0
    )
    spec = GroupSpec.conv_nc() if grouping == "nc" else None
    t = mls_quantize(x, FMT_IMAGENET, spec)
    dq = np.asarray(t.dequant())
    x = np.asarray(x)
    # re-quantization drift is bounded: S_t shifts (max element saturates to
    # (2-2^-M)/2 * S_t) so exact idempotence doesn't hold through dynamic
    # re-scaling, but the drift stays within one quantization step.
    t2 = mls_quantize(jnp.array(dq), FMT_IMAGENET, spec)
    dq2 = np.asarray(t2.dequant())
    drift = np.abs(dq2 - dq).mean() / max(np.abs(dq).mean(), 1e-12)
    assert drift < 0.04, drift
    # ARE sane for <2,4>
    are = np.abs(dq - x).mean() / np.abs(x).mean()
    assert are < 0.06


def test_grouping_reduces_error():
    """Paper Table IV: nc grouping beats per-tensor scaling."""
    key = jax.random.key(0)
    # per-(n,c) scale diversity is what group scaling exploits
    scales = jax.random.uniform(jax.random.fold_in(key, 1), (16, 16, 1, 1),
                                minval=0.01, maxval=5.0)
    x = jax.random.normal(key, (16, 16, 4, 4)) * scales
    fmt = FMT_CIFAR
    are_none = float(average_relative_error(
        x, mls_quantize(x, fmt, None).dequant()))
    are_c = float(average_relative_error(
        x, mls_quantize(x, fmt, GroupSpec((None, 1, None, None))).dequant()))
    are_nc = float(average_relative_error(
        x, mls_quantize(x, fmt, GroupSpec.conv_nc()).dequant()))
    assert are_nc < are_c < are_none


def test_elementwise_exponent_reduces_error():
    """Paper Table IV: larger Ex -> smaller ARE (no grouping).  Uses a
    scale-diverse tensor (like real training errors, paper Fig. 6)."""
    k1, k2 = jax.random.split(jax.random.key(0))
    scales = 10.0 ** jax.random.uniform(k1, (4096,), minval=-3.0, maxval=0.0)
    x = jax.random.normal(k2, (4096,)) * scales
    ares = []
    for e in [0, 1, 2, 3]:
        fmt = EMFormat(e=e, m=3)
        ares.append(float(average_relative_error(
            x, mls_quantize(x, fmt, None).dequant())))
    assert ares[3] < ares[2] < ares[1] < ares[0], ares


def test_stochastic_rounding_unbiased():
    v = 0.3172  # arbitrary off-grid value
    x = jnp.full((50_000,), v)
    # add scale diversity so the max element doesn't saturate every element
    x = jnp.concatenate([x, jnp.array([1.0])])
    t = mls_quantize(x, FMT_CIFAR, None, key=jax.random.key(0))
    mean = float(t.dequant()[:-1].mean())
    assert abs(mean - v) < 2e-3, mean


def test_pack_unpack_roundtrip():
    x = jax.random.normal(jax.random.key(5), (128, 128))
    t = mls_quantize(x, FMT_IMAGENET, GroupSpec((1, 32)))
    code = pack_elements(t)
    assert code.dtype == jnp.uint8
    s, mag = unpack_elements(code, FMT_IMAGENET)
    np.testing.assert_allclose(
        np.asarray(s * mag),
        np.asarray(t.sign.astype(jnp.float32) * t.xbar),
    )


def test_zero_and_extremes():
    for x in [jnp.zeros((4, 4)), jnp.full((4, 4), 1e30),
              jnp.full((4, 4), 1e-30), -jnp.ones((4, 4))]:
        t = mls_quantize(x, FMT_IMAGENET, None)
        dq = np.asarray(t.dequant())
        assert np.all(np.isfinite(dq))
    assert np.all(np.asarray(mls_quantize(jnp.zeros((4, 4)), FMT_IMAGENET, None).dequant()) == 0)
