"""Low-bit training op semantics (paper Alg. 1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FMT_IMAGENET, QuantConfig, lowbit_conv, lowbit_matmul


def _cos(a, b):
    a, b = a.reshape(-1), b.reshape(-1)
    return float((a @ b) / (jnp.linalg.norm(a) * jnp.linalg.norm(b)))


def test_matmul_grads_track_fp32():
    cfg = QuantConfig(fmt=FMT_IMAGENET)
    x = jax.random.normal(jax.random.key(0), (8, 64, 256))
    w = jax.random.normal(jax.random.key(1), (256, 128)) * 0.05
    f = lambda x, w: (lowbit_matmul(x, w, jax.random.key(2), cfg) ** 2).sum()
    gx, gw = jax.grad(f, argnums=(0, 1))(x, w)
    fr = lambda x, w: ((x @ w) ** 2).sum()
    gxr, gwr = jax.grad(fr, argnums=(0, 1))(x, w)
    assert _cos(gx, gxr) > 0.99
    assert _cos(gw, gwr) > 0.99


def test_conv_grads_track_fp32():
    cfg = QuantConfig(fmt=FMT_IMAGENET)
    x = jax.random.normal(jax.random.key(3), (2, 8, 12, 12))
    w = jax.random.normal(jax.random.key(4), (12, 8, 3, 3)) * 0.1
    f = lambda x, w: (lowbit_conv(x, w, jax.random.key(5), (1, 1), "SAME", cfg) ** 2).sum()
    gx, gw = jax.grad(f, argnums=(0, 1))(x, w)

    def conv(x, w):
        return jax.lax.conv_general_dilated(
            x, w, (1, 1), "SAME", dimension_numbers=("NCHW", "OIHW", "NCHW"))

    fr = lambda x, w: (conv(x, w) ** 2).sum()
    gxr, gwr = jax.grad(fr, argnums=(0, 1))(x, w)
    assert _cos(gx, gxr) > 0.99
    assert _cos(gw, gwr) > 0.99


def test_disabled_equals_fp32():
    cfg = QuantConfig(fmt=FMT_IMAGENET, enabled=False)
    x = jax.random.normal(jax.random.key(0), (16, 32))
    w = jax.random.normal(jax.random.key(1), (32, 8))
    np.testing.assert_allclose(
        np.asarray(lowbit_matmul(x, w, None, cfg)), np.asarray(x @ w),
        rtol=1e-6)


def test_bf16_compute_is_exact():
    """Tensor-scale factoring makes the bf16 GEMM bit-identical to fp32
    (paper Sec. V-B applied to the MXU)."""
    x = jax.random.normal(jax.random.key(0), (64, 256))
    w = jax.random.normal(jax.random.key(1), (256, 64)) * 0.02
    y32 = lowbit_matmul(x, w, None, QuantConfig(fmt=FMT_IMAGENET, stochastic=False))
    ybf = lowbit_matmul(x, w, None, QuantConfig(
        fmt=FMT_IMAGENET, stochastic=False, compute_dtype=jnp.bfloat16))
    np.testing.assert_array_equal(np.asarray(y32), np.asarray(ybf))


def test_stochastic_rounding_varies_with_key():
    cfg = QuantConfig(fmt=FMT_IMAGENET)
    x = jax.random.normal(jax.random.key(0), (32, 128))
    w = jax.random.normal(jax.random.key(1), (128, 32))
    y1 = lowbit_matmul(x, w, jax.random.key(10), cfg)
    y2 = lowbit_matmul(x, w, jax.random.key(11), cfg)
    y1b = lowbit_matmul(x, w, jax.random.key(10), cfg)
    assert np.any(np.asarray(y1) != np.asarray(y2))
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y1b))


def test_cotangent_dtypes_match_primals():
    cfg = QuantConfig(fmt=FMT_IMAGENET, compute_dtype=jnp.bfloat16)
    x = jax.random.normal(jax.random.key(0), (16, 64), jnp.bfloat16)
    w = jax.random.normal(jax.random.key(1), (64, 16))
    gx, gw = jax.grad(
        lambda x, w: lowbit_matmul(x, w, None, cfg).sum(), argnums=(0, 1)
    )(x, w)
    assert gx.dtype == jnp.bfloat16
    assert gw.dtype == jnp.float32
