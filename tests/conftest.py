import os
import random

# Smoke tests and benches must see ONE device; only launch/dryrun.py forces
# 512 placeholder devices (and only in its own process).  Pinning the
# platform also keeps CI runs reproducible across runner hardware.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax

jax.config.update("jax_enable_x64", False)
jax.config.update("jax_default_prng_impl", "threefry2x32")

import numpy as np
import pytest

try:  # deterministic hypothesis profile for CI reproducibility
    from hypothesis import settings as _hyp_settings

    _hyp_settings.register_profile("ci", deadline=None, derandomize=True)
    _hyp_settings.load_profile("ci")
except ImportError:  # tests fall back to tests/_hypothesis_fallback
    pass


@pytest.fixture(autouse=True)
def _deterministic_seeds():
    """Fixed non-JAX PRNG seeds per test (JAX PRNG is already key-explicit)."""
    random.seed(0)
    np.random.seed(0)
    yield
