"""MoE dispatch correctness and capacity semantics."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models.moe import apply_moe, init_moe


def _cfg(**kw):
    cfg = get_smoke_config("moonshot-v1-16b-a3b")
    return dataclasses.replace(cfg, quant=False, **kw)


def dense_reference(p, x, cfg):
    """All-experts dense computation weighted by the top-k router."""
    logits = x @ p["router"]["w"]
    probs = jax.nn.softmax(logits, -1)
    topw, topi = jax.lax.top_k(probs, cfg.top_k)
    topw = topw / topw.sum(-1, keepdims=True)
    outs = []
    for e in range(cfg.n_experts):
        h = jax.nn.silu(x @ p["w_gate"][e]) * (x @ p["w_up"][e])
        outs.append(h @ p["w_down"][e])
    outs = jnp.stack(outs, axis=2)  # (B, S, E, d)
    w_full = jnp.zeros(probs.shape).at[
        jnp.arange(x.shape[0])[:, None, None],
        jnp.arange(x.shape[1])[None, :, None], topi].set(topw)
    return jnp.einsum("bse,bsed->bsd", w_full, outs)


def test_moe_matches_dense_reference_without_drops():
    cfg = _cfg(capacity_factor=8.0)
    p = init_moe(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 12, cfg.d_model))
    y, aux = apply_moe(p, x, cfg, None, None)
    yref = dense_reference(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref),
                               rtol=2e-4, atol=2e-5)
    assert float(aux) > 0.5  # load-balance loss ~1 for near-uniform routing


def test_moe_capacity_drops_are_partial():
    """With tight capacity some tokens drop (output zero contribution) but
    the op stays finite and most mass survives."""
    cfg = _cfg(capacity_factor=0.5)
    p = init_moe(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 32, cfg.d_model))
    y, _ = apply_moe(p, x, cfg, None, None)
    yref = dense_reference(p, x, cfg)
    assert bool(jnp.all(jnp.isfinite(y)))
    # dropped-token rows differ; surviving rows match the reference
    diff = jnp.abs(y - yref).max(axis=-1)
    assert float((diff < 1e-4).mean()) > 0.3


def test_moe_quantized_runs_and_tracks():
    cfg = dataclasses.replace(_cfg(capacity_factor=8.0), quant=True)
    p = init_moe(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 12, cfg.d_model))
    y, _ = apply_moe(p, x, cfg, cfg.qcfg(), jax.random.key(2))
    yref = dense_reference(p, x, cfg)
    rel = float(jnp.linalg.norm(y - yref) / jnp.linalg.norm(yref))
    assert rel < 0.2, rel
