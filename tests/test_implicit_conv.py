"""Implicit-GEMM fused conv kernel: bit-exactness vs the im2col reference
pipeline, impl resolution precedence, the window-grid verifier hooks, the
conv autotuning plumbing, and the HBM bytes-moved model.

Property tests use hypothesis when installed, else the local shim.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    from _hypothesis_fallback import given, settings, st

import jax
import jax.numpy as jnp

from repro.core import QuantConfig
from repro.core.formats import FMT_CIFAR, FMT_IMAGENET, GS_FMT_DEFAULT
from repro.kernels.autotune import TuneSpec, conv_candidates
from repro.kernels.implicit_conv import (
    conv_geometry,
    conv_tune_dims,
    default_conv_blocks,
    im2col_conv_bytes,
    implicit_compatible,
    implicit_conv_bytes,
    implicit_conv_forward,
    resolve_conv_impl,
)
from repro.kernels.lowbit_conv import (
    _im2col,
    _ref_quantize,
    conv_fused_grads_ref,
    lowbit_conv_fused,
    lowbit_conv_fused_ref,
)

# C=4, 3x3 taps, cb=2 whole channels per group: the smallest non-trivial
# legal implicit grouping (k_block = 2*3*3 = 18)
_C, _K, _KB, _O = 4, 3, 18, 6


def _cfg(**kw):
    base = dict(fmt=FMT_IMAGENET, k_block=_KB, grouping="nc",
                stochastic=False, backend="pallas", pallas_interpret=True,
                conv_impl="implicit", block_n=8)
    base.update(kw)
    return QuantConfig(**base)


def _conv_data(h, w, seed, n=2):
    kx, kw_, kg = jax.random.split(jax.random.key(seed), 3)
    x = jax.random.normal(kx, (n, _C, h, w), jnp.float32)
    wt = jax.random.normal(kw_, (_O, _C, _K, _K), jnp.float32) * 0.3
    return x, wt, kg


# ---------------------------------------------------------------------------
# property: implicit path bit-identical to the reference backend over
# stride x padding x ragged spatial shapes (codes, scales, y, both grads)
# ---------------------------------------------------------------------------
@settings(max_examples=8, deadline=None)
@given(st.integers(5, 9), st.integers(4, 8), st.sampled_from([1, 2]),
       st.sampled_from(["SAME", "VALID", "explicit"]),
       st.integers(0, 2**31 - 1))
def test_implicit_bit_identical_to_ref(h, w, s, pad_kind, seed):
    pad = [(2, 2), (2, 2)] if pad_kind == "explicit" else pad_kind
    geom = conv_geometry((2, _C, h, w), (_O, _C, _K, _K), (s, s), pad)
    if geom.oh < 1 or geom.ow < 1:
        return  # empty output window: nothing to compare
    x, wt, kg = _conv_data(h, w, seed)
    cfg = _cfg()
    y = lowbit_conv_fused(x, wt, None, stride=(s, s), padding=pad, cfg=cfg)
    yr = lowbit_conv_fused_ref(x, wt, None, stride=(s, s), padding=pad,
                               cfg=cfg)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yr))

    e = jax.random.normal(kg, y.shape, jnp.float32)

    def loss(a, b):
        out = lowbit_conv_fused(a, b, None, stride=(s, s), padding=pad,
                                cfg=cfg)
        return jnp.sum(out * e)

    dx, dw = jax.grad(loss, argnums=(0, 1))(x, wt)
    dxr, dwr = conv_fused_grads_ref(x, wt, e, None, stride=(s, s),
                                    padding=pad, cfg=cfg)
    np.testing.assert_array_equal(np.asarray(dx), np.asarray(dxr))
    np.testing.assert_array_equal(np.asarray(dw), np.asarray(dwr))


@settings(max_examples=6, deadline=None)
@given(st.integers(5, 8), st.sampled_from([1, 2]),
       st.sampled_from(["SAME", "VALID"]), st.integers(0, 2**31 - 1))
def test_implicit_codes_and_scales_match_im2col_quantizer(h, s, pad, seed):
    """The fused prologue's emitted codes, group scales, and tensor scale
    equal quantizing the materialized im2col matrix (paper Alg. 2)."""
    geom = conv_geometry((2, _C, h, h), (_O, _C, _K, _K), (s, s), pad)
    if geom.oh < 1 or geom.ow < 1:
        return
    x, wt, _ = _conv_data(h, h, seed)
    fmt = FMT_CIFAR if seed % 2 else FMT_IMAGENET
    _, codes, sg, st_ = implicit_conv_forward(
        x, wt, None, None, (s, s), pad, fmt=fmt, k_block=_KB,
        block_n=8, grouping="nc", interpret=True, emit_codes=True)
    cols, _ = _im2col(x, (_K, _K), (s, s), pad)
    bm = default_conv_blocks(geom)[0] * geom.ow
    rc, rsg, rst = _ref_quantize(cols, fmt, _KB, GS_FMT_DEFAULT, None,
                                 block_m=bm, grouping="nc", interpret=False)
    np.testing.assert_array_equal(np.asarray(st_), np.asarray(rst))
    np.testing.assert_array_equal(np.asarray(codes), np.asarray(rc))
    np.testing.assert_array_equal(np.asarray(sg), np.asarray(rsg))


@pytest.mark.parametrize("grouping", ["nc", "c", "n", "none"])
def test_all_groupings_bit_identical(grouping):
    x, wt, kg = _conv_data(9, 7, 3)
    cfg = _cfg(grouping=grouping)
    y = lowbit_conv_fused(x, wt, None, stride=(1, 1), padding="SAME",
                          cfg=cfg)
    yr = lowbit_conv_fused_ref(x, wt, None, stride=(1, 1), padding="SAME",
                               cfg=cfg)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yr))
    e = jax.random.normal(kg, y.shape, jnp.float32)
    dx, dw = jax.grad(
        lambda a, b: jnp.sum(lowbit_conv_fused(
            a, b, None, stride=(1, 1), padding="SAME", cfg=cfg) * e),
        argnums=(0, 1))(x, wt)
    dxr, dwr = conv_fused_grads_ref(x, wt, e, None, stride=(1, 1),
                                    padding="SAME", cfg=cfg)
    # grouping "none" exercises the wgrad forward-code-reuse fast path
    np.testing.assert_array_equal(np.asarray(dx), np.asarray(dxr))
    np.testing.assert_array_equal(np.asarray(dw), np.asarray(dwr))


def test_stochastic_forward_bit_identical():
    x, wt, _ = _conv_data(8, 8, 5)
    # ref tiles (block_m=64=OH*OW... bm divides M0=128, kb | K0) line up
    # with the virtual GEMM, so the r-draws agree bit-for-bit
    cfg = _cfg(stochastic=True, block_m=64)
    key = jax.random.key(7)
    y = lowbit_conv_fused(x, wt, key, stride=(1, 1), padding="SAME",
                          cfg=cfg)
    yr = lowbit_conv_fused_ref(x, wt, key, stride=(1, 1), padding="SAME",
                               cfg=cfg)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yr))


# ---------------------------------------------------------------------------
# impl resolution: env > cfg > tuned cache > legality default
# ---------------------------------------------------------------------------
def test_resolve_impl_auto_falls_back_on_incompatible_k_block():
    geom = conv_geometry((2, _C, 8, 8), (_O, _C, 3, 3), (1, 1), "SAME")
    ok, reason = implicit_compatible(geom, 32)
    assert not ok and "not a multiple" in reason
    assert resolve_conv_impl(geom, _cfg(conv_impl="auto", k_block=32,
                                        block_n=None)) == "im2col"
    assert resolve_conv_impl(geom, _cfg(k_block=18)) == "implicit"


def test_resolve_impl_explicit_implicit_raises_on_incompatible():
    geom = conv_geometry((2, _C, 8, 8), (_O, _C, 3, 3), (1, 1), "SAME")
    with pytest.raises(ValueError, match="not legal"):
        resolve_conv_impl(geom, _cfg(conv_impl="implicit", k_block=32,
                                     block_n=None))


def test_resolve_impl_env_overrides_cfg(monkeypatch):
    geom = conv_geometry((2, _C, 8, 8), (_O, _C, 3, 3), (1, 1), "SAME")
    monkeypatch.setenv("REPRO_CONV_IMPL", "im2col")
    assert resolve_conv_impl(geom, _cfg(conv_impl="implicit")) == "im2col"
    monkeypatch.setenv("REPRO_CONV_IMPL", "bogus")
    with pytest.raises(ValueError, match="REPRO_CONV_IMPL"):
        resolve_conv_impl(geom, _cfg())


def test_quant_config_rejects_unknown_conv_impl():
    with pytest.raises(ValueError, match="conv_impl"):
        _cfg(conv_impl="winograd")


def test_impl_choice_never_changes_numerics():
    """A/B: forcing im2col and implicit on the same legal config produces
    bit-identical outputs — impl selection is pure layout."""
    x, wt, _ = _conv_data(8, 8, 11)
    ya = lowbit_conv_fused(x, wt, None, stride=(1, 1), padding="SAME",
                           cfg=_cfg(conv_impl="implicit"))
    yb = lowbit_conv_fused(x, wt, None, stride=(1, 1), padding="SAME",
                           cfg=_cfg(conv_impl="im2col"))
    np.testing.assert_array_equal(np.asarray(ya), np.asarray(yb))


# ---------------------------------------------------------------------------
# conv autotuning plumbing
# ---------------------------------------------------------------------------
def test_conv_candidates_keep_k_block_fixed():
    geom = conv_geometry((2, 16, 8, 8), (16, 16, 3, 3), (1, 1), "SAME")
    spec = TuneSpec("conv", conv_tune_dims(geom, 36), FMT_IMAGENET,
                    k_block=36)
    cands = conv_candidates(spec)
    assert cands[0].impl == "im2col"
    impls = {c.impl for c in cands}
    assert impls == {"im2col", "implicit"}
    # k_block is the scaling-group width: the conv search must never move it
    assert all(c.k_block == 36 for c in cands)
    for c in cands:
        if c.impl == "implicit":
            assert geom.oh % c.block_m == 0  # block_m stores bh for convs


def test_conv_spec_shape_must_embed_k_block():
    geom = conv_geometry((2, 16, 8, 8), (16, 16, 3, 3), (1, 1), "SAME")
    with pytest.raises(ValueError, match="shape\\[13\\]"):
        TuneSpec("conv", conv_tune_dims(geom, 36), FMT_IMAGENET, k_block=72)


def test_verify_implicit_conv_candidate_proves_and_rejects():
    from repro.analysis.kernel_verify import verify_implicit_conv_candidate

    geom = conv_geometry((2, 16, 8, 8), (16, 16, 3, 3), (1, 1), "SAME")
    good = verify_implicit_conv_candidate(geom, FMT_IMAGENET, 36, 2, 16)
    assert good.ok and good.max_integer_bits < 24
    # bh that does not divide OH must be named, not silently padded
    bad = verify_implicit_conv_candidate(geom, FMT_IMAGENET, 36, 3, 16)
    assert not bad.ok
    assert "divisibility" in {v.kind for v in bad.violations}


def test_window_proof_drop_halo_is_oob():
    from repro.analysis.kernel_verify import prove_window_grid

    geom = conv_geometry((2, _C, 8, 8), (_O, _C, 3, 3), (1, 1), "SAME")
    clean, cov = prove_window_grid(geom, 2, 2, 8)
    assert not clean and cov["blocks_written"] == geom.n * geom.oh
    short, _ = prove_window_grid(geom, 2, 2, 8, band_h_override=3)
    assert any(v.kind == "oob" for v in short)


# ---------------------------------------------------------------------------
# HBM bytes-moved model (the acceptance target)
# ---------------------------------------------------------------------------
def test_implicit_moves_3x_fewer_bytes_on_resnet20_shape():
    geom = conv_geometry((8, 16, 32, 32), (16, 16, 3, 3), (1, 1), "SAME")
    im = im2col_conv_bytes(geom, 36)
    imp = implicit_conv_bytes(geom, 36)
    assert im["total"] / imp["total"] >= 3.0
    # the im2col gap is the patch matrix: kh*kw-fold fp32 duplication
    assert im["im2col_materialize"] > imp["total"]
    # the kernel reads each image exactly once
    assert imp["kernel_x_fetch"] == 4 * geom.n * geom.c * geom.hp * geom.wp
