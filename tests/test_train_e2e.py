"""End-to-end training: loss decreases under MLS quantization; restart from
checkpoint reproduces the exact continuation (deterministic SR streams)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import RunConfig, SHAPES
from repro.data import make_lm_iterator
from repro.models import lm
from repro.train import CheckpointManager, StragglerMonitor, make_train_step


def _mini_run(arch="glm4-9b", steps=8, microbatch=0):
    cfg = dataclasses.replace(get_smoke_config(arch), vocab=128)
    run = RunConfig(model=cfg, shape=SHAPES["train_4k"], microbatch=microbatch,
                    optimizer="adamw", lr=1e-2)
    train_step, opt_init = make_train_step(run)
    step = jax.jit(train_step)
    params = lm.init_lm(jax.random.key(0), cfg)
    opt = opt_init(params)
    nxt, dstate = make_lm_iterator(batch=8, seq=32, vocab=cfg.vocab)
    losses = []
    for _ in range(steps):
        batch, dstate = nxt(dstate)
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
    return cfg, run, params, opt, dstate, losses


def test_loss_decreases_quantized():
    _, _, _, _, _, losses = _mini_run(steps=25)
    best = min(losses[-5:])
    assert best < losses[0] - 0.5, losses


def test_microbatch_equivalence():
    """Gradient accumulation changes memory, not semantics (same data)."""
    cfg = get_smoke_config("chatglm3-6b")
    run0 = RunConfig(model=cfg, shape=SHAPES["train_4k"], microbatch=0, lr=1e-2)
    run4 = dataclasses.replace(run0, microbatch=4)
    s0, oi0 = make_train_step(run0)
    s4, oi4 = make_train_step(run4)
    params = lm.init_lm(jax.random.key(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.key(1), (8, 32), 0, cfg.vocab)}
    p0, _, m0 = jax.jit(s0)(params, oi0(params), batch)
    p4, _, m4 = jax.jit(s4)(params, oi4(params), batch)
    # stochastic rounding keys differ per microbatch layout; compare loosely
    d = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max() /
                                        (jnp.abs(a).max() + 1e-9)), p0, p4)
    assert max(jax.tree.leaves(d)) < 0.35
    assert abs(float(m0["loss"]) - float(m4["loss"])) < 0.2


def test_checkpoint_restart_bitexact(tmp_path):
    cfg, run, params, opt, dstate, _ = _mini_run(steps=4)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(4, {"params": params, "opt": opt, "data": dstate})

    train_step, _ = make_train_step(run)
    step = jax.jit(train_step)
    nxt, _ = make_lm_iterator(batch=8, seq=32, vocab=cfg.vocab)

    # continue directly
    p_a, o_a, d_a = params, opt, dstate
    for _ in range(3):
        b, d_a = nxt(d_a)
        p_a, o_a, _ = step(p_a, o_a, b)

    # restore and continue
    r = mgr.restore({"params": params, "opt": opt, "data": dstate})
    p_b, o_b, d_b = r["params"], r["opt"], r["data"]
    for _ in range(3):
        b, d_b = nxt(d_b)
        p_b, o_b, _ = step(p_b, o_b, b)

    for a, b in zip(jax.tree.leaves(p_a), jax.tree.leaves(p_b)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_straggler_monitor_flags_slow_steps():
    import time

    mon = StragglerMonitor(warmup_steps=1, threshold=1.5)
    for i in range(6):
        mon.start()
        time.sleep(0.02 if i != 4 else 0.12)
        mon.stop()
    rep = mon.report()
    assert 5 in rep["straggler_steps"], rep
