"""Static kernel verifier: coverage proofs, interval overflow prover,
sabotage negative controls and agreement with the closed-form lint.

Property tests use hypothesis when installed, else the local shim.
"""
import json

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    from _hypothesis_fallback import given, settings, st

import jax
import jax.numpy as jnp

from repro.analysis import audit
from repro.analysis.intervals import Interval, abstract_eval_jaxpr, integer_bits
from repro.analysis.kernel_verify import (
    _sabotage_deep_k_jaxpr,
    _sabotage_overlap_jaxpr,
    prove_matmul_accumulation_bits,
    run_kernel_audit,
    verify_candidate,
    verify_closed_jaxpr,
    verify_entry,
)
from repro.analysis.lint import check_format_pair
from repro.core import FMT_IMAGENET
from repro.core.formats import EMFormat, accumulation_bits
from repro.kernels import KERNEL_REGISTRY
from repro.kernels.ref import decode_frac_int


# ---------------------------------------------------------------------------
# registry + shipped kernels all verify clean
# ---------------------------------------------------------------------------
EXPECTED_KERNELS = {
    "mls_quantize_pallas",
    "mls_matmul_pallas",
    "lowbit_matmul_fused",
    "lowbit_conv_fused",
    "lowbit_conv_implicit",
    "lowbit_matmul_qd",
}


def test_registry_covers_shipped_kernels():
    assert set(KERNEL_REGISTRY) == EXPECTED_KERNELS
    for name, entry in KERNEL_REGISTRY.items():
        assert entry.name == name
        fn, avals = entry.fn_and_args()
        assert callable(fn) and avals


def test_shipped_kernels_verify_clean():
    report = run_kernel_audit()
    assert report["budget_bits"] == 24
    assert set(report["kernels"]) == EXPECTED_KERNELS
    bad = {n: r["calls"] for n, r in report["kernels"].items() if not r["ok"]}
    assert report["ok"] and not bad, bad
    for rep in report["kernels"].values():
        assert rep["num_pallas_calls"] >= 1
        assert rep["max_integer_accumulation_bits"] < 24


def test_quantize_entry_report_shape():
    rep = verify_entry(KERNEL_REGISTRY["mls_quantize_pallas"])
    assert rep.ok and len(rep.calls) == 1
    call = rep.calls[0].to_json()
    # grid coverage was proven exhaustively, not assumed
    assert call["exhaustive"]
    cov = call["coverage"]["outputs[0]"]
    assert cov["blocks_written"] == cov["output_blocks"]


# ---------------------------------------------------------------------------
# sabotage negative controls
# ---------------------------------------------------------------------------
def test_sabotage_overlap_names_overlap_and_gap():
    rep = verify_closed_jaxpr(_sabotage_overlap_jaxpr(), "sabotage")
    kinds = {v.kind for v in rep.violations}
    assert not rep.ok
    assert {"overlap", "gap"} <= kinds, kinds


def test_sabotage_deep_k_names_overflow():
    rep = verify_closed_jaxpr(_sabotage_deep_k_jaxpr(), "sabotage")
    assert not rep.ok
    kinds = {v.kind for v in rep.violations}
    assert "overflow" in kinds, kinds
    # <2,4> at k_block=2048: 14 product bits + 11 depth bits = 25
    assert rep.max_integer_bits == accumulation_bits(FMT_IMAGENET, 2048) == 25


def test_sabotage_drop_halo_names_oob():
    from repro.analysis.kernel_verify import _sabotage_drop_halo_report

    rep = _sabotage_drop_halo_report()
    assert not rep.ok
    kinds = {v.kind for v in rep.violations}
    assert "oob" in kinds, kinds
    # the violation names the short halo band, not a generic bound error
    assert any("halo band" in v.detail for v in rep.violations)


@pytest.mark.parametrize("mode", ["overlap_write", "deep_k", "drop_halo"])
def test_audit_gate_trips_on_sabotage(mode, tmp_path):
    out = tmp_path / f"report_{mode}.json"
    rc = audit.main([
        "--kernels", "--graph", "none", "--no-wire", "--gate",
        "--sabotage", mode, "--out", str(out),
    ])
    assert rc != 0
    report = json.loads(out.read_text())
    sab = report["kernels"]["kernels"][f"sabotage:{mode}"]
    assert not sab["ok"]


def test_audit_gate_green_without_sabotage(tmp_path):
    out = tmp_path / "report_clean.json"
    rc = audit.main([
        "--kernels", "--graph", "none", "--no-wire", "--gate",
        "--out", str(out),
    ])
    assert rc == 0


# ---------------------------------------------------------------------------
# interval prover == closed-form lint on a (fmt, k_block) sweep
# ---------------------------------------------------------------------------
# m >= 1 keeps the closed form tight (m=0 formats are conservatively
# over-counted by ~2 bits and rejected by the storage lint anyway);
# the boundary pairs straddle the 24-bit budget from both sides.
SWEEP_PAIRS = [
    (EMFormat(0, 4), 16),
    (EMFormat(1, 3), 128),
    (EMFormat(2, 4), 128),   # FMT_IMAGENET at the paper depth
    (EMFormat(2, 4), 512),   # 23 bits: legal boundary
    (EMFormat(2, 5), 256),   # 24 bits: illegal boundary
    (EMFormat(3, 1), 256),
    (EMFormat(3, 2), 64),
    (EMFormat(3, 3), 16),
]


@pytest.mark.parametrize(
    "fmt,k_block", SWEEP_PAIRS, ids=[f"{f}_kb{k}" for f, k in SWEEP_PAIRS]
)
def test_prover_agrees_with_closed_form(fmt, k_block):
    proved = prove_matmul_accumulation_bits(fmt, k_block)
    assert proved == accumulation_bits(fmt, k_block)
    # the prover flags exactly the pairs the lint's closed form flags
    lint_flags = any("no longer" in e for e in check_format_pair(fmt, k_block))
    assert (proved >= 24) == lint_flags


# ---------------------------------------------------------------------------
# autotuner legality oracle
# ---------------------------------------------------------------------------
def test_verify_candidate_legal_tiling():
    rep = verify_candidate((64, 256, 64), (FMT_IMAGENET, 128), blocks=(64, 64))
    assert rep.ok
    assert rep.max_integer_bits == accumulation_bits(FMT_IMAGENET, 128)


def test_verify_candidate_rejects_deep_accumulation():
    rep = verify_candidate((64, 4096, 64), (EMFormat(2, 5), 2048),
                           blocks=(64, 64))
    assert not rep.ok
    assert "overflow" in {v.kind for v in rep.violations}


def test_verify_candidate_accepts_quant_config():
    from repro.core import QuantConfig

    cfg = QuantConfig(fmt=FMT_IMAGENET, backend="pallas", k_block=32,
                      pallas_interpret=True)
    rep = verify_candidate((32, 64, 32), cfg, blocks=(32, 32))
    assert rep.ok


# ---------------------------------------------------------------------------
# interval-analysis soundness: concrete runs stay inside the abstract bounds
# ---------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(st.integers(0, 3), st.integers(1, 4), st.integers(0, 2**31 - 1))
def test_decode_interval_contains_concrete_values(e, m, seed):
    """The static decode bound (read off the reduce_sum accumulation
    event's operand bound) contains every concrete decode of random uint8
    codes — and is exactly the ±max_fraction hull, not a loose cover."""
    fmt = EMFormat(e, m)
    codes = np.random.default_rng(seed).integers(0, 256, (4, 8), np.uint8)

    def fn(c):
        return jnp.sum(decode_frac_int(c, fmt).astype(jnp.float32))

    cj = jax.make_jaxpr(fn)(jax.ShapeDtypeStruct(codes.shape, jnp.uint8))
    _, res = abstract_eval_jaxpr(cj.jaxpr, [Interval.of_dtype(np.uint8)])
    accs = [a for a in res.accumulations if a.kind == "acc"]
    assert accs, "reduce_sum accumulation event not recorded"
    static_bound = max(a.operand_bound for a in accs)
    concrete = np.asarray(decode_frac_int(jnp.asarray(codes), fmt))
    assert float(np.abs(concrete).max()) <= static_bound
    lo, hi = fmt.fraction_bound()
    assert concrete.min() >= lo and concrete.max() <= hi
    assert static_bound == float(fmt.max_fraction)  # exact, not just sound


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 3), st.integers(1, 4),
       st.sampled_from([8, 16, 32]), st.integers(0, 2**31 - 1))
def test_dot_interval_bound_is_sound(e, m, depth, seed):
    """A depth-k integer dot of decoded fractions never exceeds the
    interval prover's accumulation bound for that (fmt, depth)."""
    fmt = EMFormat(e, m)
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 256, (4, depth), np.uint8)
    b = rng.integers(0, 256, (depth, 4), np.uint8)

    def fn(ca, cb):
        fa = decode_frac_int(ca, fmt).astype(jnp.float32)
        fb = decode_frac_int(cb, fmt).astype(jnp.float32)
        return fa @ fb

    cj = jax.make_jaxpr(fn)(
        jax.ShapeDtypeStruct(a.shape, jnp.uint8),
        jax.ShapeDtypeStruct(b.shape, jnp.uint8),
    )
    _, res = abstract_eval_jaxpr(
        cj.jaxpr, [Interval.of_dtype(np.uint8)] * 2)
    dots = [acc for acc in res.accumulations if acc.kind == "dot"]
    assert dots, "dot_general accumulation event not recorded"
    bound = max(acc.bound for acc in dots)
    concrete = np.asarray(fn(jnp.asarray(a), jnp.asarray(b)))
    assert float(np.abs(concrete).max()) <= bound
    # the recorded bound matches the closed form's worst case exactly
    fmax = fmt.max_fraction
    assert bound == depth * fmax * fmax
    assert max(acc.bits for acc in dots) == accumulation_bits(fmt, depth)


# ---------------------------------------------------------------------------
# review regressions: seed-image alignment, xor, shift wrap, replay fixpoint
# ---------------------------------------------------------------------------
def test_rearranged_slices_not_pointwise_aligned():
    """Two different slices of one seed must not be treated as pointwise
    equal: sum(x[0:4] - x[4:8]) over uint8 is concretely up to 4*255, not
    0 (the bound the aligned-image domain used to prove)."""
    def fn(c):
        x = c.astype(jnp.int32)
        return jnp.sum(x[0:4] - x[4:8])

    cj = jax.make_jaxpr(fn)(jax.ShapeDtypeStruct((8,), jnp.uint8))
    _, res = abstract_eval_jaxpr(cj.jaxpr, [Interval.of_dtype(np.uint8)])
    accs = [a for a in res.accumulations if a.kind == "acc"]
    assert accs, "reduce_sum accumulation event not recorded"
    bound = max(a.bound for a in accs)
    rng = np.random.default_rng(0)
    x = rng.integers(0, 256, (8,), np.uint8)
    concrete = abs(int(np.asarray(fn(jnp.asarray(x)))))
    assert concrete <= bound
    assert bound >= 4 * 255  # the sound worst case, not the aligned 0


def test_transposed_image_not_pointwise_aligned():
    """x @ x.T pairs rearranged elements of one seed; the dot bound must
    cover the concrete worst case 255*255*K, not collapse via alignment."""
    def fn(c):
        x = c.astype(jnp.int32).astype(jnp.float32)
        return x @ x.T

    cj = jax.make_jaxpr(fn)(jax.ShapeDtypeStruct((4, 4), jnp.uint8))
    _, res = abstract_eval_jaxpr(cj.jaxpr, [Interval.of_dtype(np.uint8)])
    dots = [a for a in res.accumulations if a.kind == "dot"]
    assert dots and max(a.bound for a in dots) >= 4 * 255 * 255


def test_xor_interval_lower_bound_is_zero():
    """x ^ y can be smaller than both operands (5 ^ 5 = 0); the xor rule
    must not inherit OR's max(lo_a, lo_b) lower bound."""
    a = Interval(5.0, 7.0, True)
    r = a.bit_xor(a)
    assert r.lo == 0.0 and r.hi >= 7.0

    def fn(x, y):
        return jnp.sum(jnp.bitwise_xor(x, y).astype(jnp.int32))

    cj = jax.make_jaxpr(fn)(
        jax.ShapeDtypeStruct((4,), jnp.uint8),
        jax.ShapeDtypeStruct((4,), jnp.uint8),
    )
    _, res = abstract_eval_jaxpr(
        cj.jaxpr, [Interval(5.0, 7.0, True), Interval(5.0, 7.0, True)])
    accs = [a for a in res.accumulations if a.kind == "acc"]
    assert accs
    # sum of 4 xors each in [0, 7]: lo must reach 0 (all pairs equal)
    assert all(acc.bound <= 4 * 7 for acc in accs)


def test_bit_op_intervals_sound_bruteforce():
    ranges = [(0, 7), (3, 12), (5, 7), (1, 1)]
    for alo, ahi in ranges:
        for blo, bhi in ranges:
            ia = Interval(float(alo), float(ahi), True)
            ib = Interval(float(blo), float(bhi), True)
            for op, f in [
                (ia.bit_and(ib), lambda x, y: x & y),
                (ia.bit_or(ib), lambda x, y: x | y),
                (ia.bit_xor(ib), lambda x, y: x ^ y),
            ]:
                for x in range(alo, ahi + 1):
                    for y in range(blo, bhi + 1):
                        assert op.lo <= f(x, y) <= op.hi, (x, y, op)


def test_np_shift_left_never_wraps():
    """Huge shifts must saturate to inf (image path bails to intervals),
    never wrap int64 into finite garbage that poisons the 'exact' hull."""
    from repro.analysis.intervals import _np_shift_left

    exact = _np_shift_left(np.array([4096.0]), np.array([55.0]))
    assert exact[0] == 4096.0 * 2.0**55  # would wrap in int64
    huge = _np_shift_left(np.array([3.0]), np.array([2000.0]))
    assert not np.isfinite(huge[0])
    assert _np_shift_left(np.array([0.0]), np.array([2000.0]))[0] == 0.0


def _replayed_acc_jaxpr(repeat):
    """Kernel whose int32 output accumulates every step and is never
    re-initialized, under an unused grid axis replaying the subgrid
    ``repeat`` times — the pattern the replay fixpoint must gate."""
    from jax.experimental import pallas as pl

    def kernel(x_ref, o_ref):
        k = pl.program_id(1)  # keeps axis 1 a *used* axis in the body
        o_ref[...] += x_ref[...].astype(jnp.int32) + k

    def fn(x):
        return pl.pallas_call(
            kernel,
            grid=(repeat, 4),
            in_specs=[pl.BlockSpec((8, 8), lambda r, k: (0, 0))],
            out_specs=pl.BlockSpec((8, 8), lambda r, k: (0, 0)),
            out_shape=jax.ShapeDtypeStruct((8, 8), jnp.int32),
            interpret=True,
        )(x)

    return jax.make_jaxpr(fn)(jax.ShapeDtypeStruct((8, 8), jnp.uint8))


def test_widening_replays_beyond_cap_gate_as_unproven():
    rep = verify_closed_jaxpr(_replayed_acc_jaxpr(64), "widening")
    assert not rep.ok
    assert "unproven" in {v.kind for v in rep.violations}


def test_widening_replays_within_cap_fully_covered():
    # 4 <= replay cap: every concrete replay is abstractly executed, so the
    # recorded bounds cover the whole grid and nothing is left unproven
    rep = verify_closed_jaxpr(_replayed_acc_jaxpr(4), "covered")
    assert "unproven" not in {v.kind for v in rep.violations}


def test_interval_arithmetic_soundness_small():
    """Brute-force check of a few Interval ops against enumeration."""
    xs = [-3.0, -1.0, 0.0, 2.0, 5.0]
    a = Interval(-3.0, 5.0, True)
    b = Interval(-1.0, 2.0, True)
    ys = [-1.0, 0.0, 2.0]
    for op, f in [
        (a + b, lambda x, y: x + y),
        (a - b, lambda x, y: x - y),
        (a * b, lambda x, y: x * y),
        (a.min_(b), min),
        (a.max_(b), max),
    ]:
        for x in xs:
            for y in ys:
                v = f(x, y)
                assert op.lo <= v <= op.hi, (op, v)
    assert a.abs().lo == 0.0 and a.abs().hi == 5.0
    assert integer_bits(255.0) == 8 and integer_bits(256.0) == 9
