"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches JAX device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first JAX
init, and smoke tests must keep seeing 1 device.

Single pod: 16 x 16 = 256 chips, axes ("data", "model").
Multi-pod:  2 x 16 x 16 = 512 chips, axes ("pod", "data", "model") — the
leading ``pod`` axis is pure data parallelism over the DCN (see
repro/parallel/specs.py).
"""
from __future__ import annotations

import jax


def make_mesh(shape, axes) -> jax.sharding.Mesh:
    # jax >= 0.5 grew explicit axis types; Auto matches the implicit
    # behaviour of older releases, so omit the kwarg when it doesn't exist.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests / examples)."""
    return make_mesh((data, model), ("data", "model"))
