"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches JAX device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first JAX
init, and smoke tests must keep seeing 1 device.

Single pod: 16 x 16 = 256 chips, axes ("data", "model").
Multi-pod:  2 x 16 x 16 = 512 chips, axes ("pod", "data", "model") — the
leading ``pod`` axis is pure data parallelism over the DCN (see
repro/parallel/specs.py).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_local_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests / examples)."""
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )
