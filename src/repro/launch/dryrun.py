import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

DOC = """Multi-pod AOT dry-run: ``.lower().compile()`` every (arch x shape x
mesh) cell on the production meshes, zero real allocation (ShapeDtypeStructs).

For each cell this records into a JSON artifact (experiments/dryrun/):
* ``memory_analysis`` — per-device argument/output/temp bytes (fit proof),
* ``cost_analysis``   — raw XLA FLOPs/bytes (while-body counted once; see
  hlo_analysis for the trip-corrected numbers),
* ``hlo``             — trip-corrected dot FLOPs + per-collective wire bytes,
* roofline terms (compute / memory / collective seconds) and the dominant
  bottleneck, using the TPU v5e-class constants from the brief.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-72b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs import (
    ARCHS,
    SHAPES,
    RunConfig,
    get_config,
    runnable_shapes,
    shape_model_config,
)
from repro.launch import roofline as rf
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (
    abstract_params,
    batch_specs,
    cache_specs,
    choose_microbatch,
)
from repro.parallel import DEFAULT_RULES, axis_rules
from repro.parallel.specs import batch_shardings, cache_shardings, param_shardings
from repro.train import make_serve_step, make_train_step
from repro.parallel.sharding import AxisRules

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    rules: AxisRules | None = None,
    microbatch: int | None = None,
    tag: str = "",
    out_dir: str | None = None,
    verbose: bool = True,
    cfg_updates: dict[str, Any] | None = None,
    seq_shard: bool = False,
) -> dict[str, Any]:
    """Lower + compile one cell; returns (and persists) the analysis record.

    ``cfg_updates``: ModelConfig field overrides (perf-iteration levers).
    ``seq_shard``: bind the sequence-parallel rules variant.
    """
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    if seq_shard and rules is None:
        from repro.parallel.sharding import SP_RULES

        rules = SP_RULES
    rules = rules or DEFAULT_RULES
    cfg = shape_model_config(get_config(arch), SHAPES[shape_name])
    if cfg_updates:
        cfg = dataclasses.replace(cfg, **cfg_updates)
    shape = SHAPES[shape_name]
    record: dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": mesh.devices.size, "tag": tag,
    }

    with mesh, axis_rules(rules, mesh):
        params = abstract_params(cfg)
        p_shard = param_shardings(params, mesh, rules)
        if shape.kind == "train":
            mb = choose_microbatch(cfg, shape, mesh, seq_shard) \
                if microbatch is None else microbatch
            record["microbatch"] = mb
            run = RunConfig(model=cfg, shape=shape, microbatch=mb)
            train_step, opt_init = make_train_step(run)
            opt = jax.eval_shape(opt_init, params)
            o_shard = _opt_shardings(opt, params, p_shard, mesh)
            batch = batch_specs(cfg, shape)
            b_shard = batch_shardings(batch, mesh, rules)
            step = jax.jit(
                train_step,
                in_shardings=(p_shard, o_shard, b_shard),
                out_shardings=(p_shard, o_shard, None),
                donate_argnums=(0, 1),
            )
            lowered = step.lower(params, opt, batch)
        else:
            serve_step = make_serve_step(cfg)
            if shape.kind == "prefill":
                from repro.train import make_prefill_step

                pf = make_prefill_step(cfg, max_len=shape.seq_len)
                batch = batch_specs(cfg, shape)
                b_shard = batch_shardings(batch, mesh, rules)
                step = jax.jit(pf, in_shardings=(p_shard, b_shard))
                lowered = step.lower(params, batch)
            else:  # decode
                cache = cache_specs(cfg, shape)
                c_shard = cache_shardings(cache, mesh, rules)
                batch = batch_specs(cfg, shape)
                b_shard = batch_shardings(batch, mesh, rules)
                step = jax.jit(
                    serve_step,
                    in_shardings=(p_shard, c_shard, b_shard["tokens"]),
                    out_shardings=(None, c_shard),
                    donate_argnums=(1,),
                )
                lowered = step.lower(params, cache, batch["tokens"])

        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()

    ma = compiled.memory_analysis()
    record["memory_analysis"] = {
        "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
        "output_bytes": getattr(ma, "output_size_in_bytes", None),
        "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
        "alias_bytes": getattr(ma, "alias_size_in_bytes", None),
    }
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # jax < 0.5 returns one dict per program
        ca = ca[0] if ca else {}
    record["cost_analysis"] = {
        "flops_raw": float(ca.get("flops", 0.0)),
        "bytes_accessed_raw": float(ca.get("bytes accessed", 0.0)),
    }
    hlo_text = compiled.as_text()
    record["hlo"] = analyze_hlo(hlo_text)
    record["hlo_chars"] = len(hlo_text)
    record["lower_s"] = round(t1 - t0, 2)
    record["compile_s"] = round(t2 - t1, 2)
    record["roofline"] = rf.roofline_terms(cfg, shape, mesh, record)

    if verbose:
        r = record["roofline"]
        print(
            f"[dryrun] {arch} x {shape_name} x {record['mesh']}{tag}: "
            f"compile {record['compile_s']}s, "
            f"compute {r['compute_s']:.2e}s mem {r['memory_s']:.2e}s "
            f"coll {r['collective_s']:.2e}s -> {r['bottleneck']} "
            f"(roofline frac {r['roofline_fraction']:.2f}, "
            f"util {r['model_flops_ratio']:.2f})",
            flush=True,
        )
    out_dir = out_dir or OUT_DIR
    os.makedirs(out_dir, exist_ok=True)
    fname = f"{arch}_{shape_name}_{record['mesh']}{('_' + tag) if tag else ''}.json"
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump(record, f, indent=1)
    return record


def _opt_shardings(opt, params, p_shard, mesh):
    """Optimizer state mirrors parameter shardings; scalars replicate."""
    rep = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())

    def mirror(tree):
        # mu/nu have the same tree structure as params
        return jax.tree.map(lambda s, ps: ps, tree, p_shard)

    from repro.optim import OptState

    mu = mirror(opt.mu) if jax.tree_util.tree_structure(opt.mu) == \
        jax.tree_util.tree_structure(params) else jax.tree.map(lambda _: rep, opt.mu)
    nu = mirror(opt.nu) if jax.tree_util.tree_structure(opt.nu) == \
        jax.tree_util.tree_structure(params) else jax.tree.map(lambda _: rep, opt.nu)
    return OptState(rep, mu, nu)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    cells = []
    if args.all:
        for arch, cfg in ARCHS.items():
            for sh in runnable_shapes(cfg):
                cells.append((arch, sh.name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))

    failures = []
    for arch, shape in cells:
        for mp in meshes:
            try:
                run_cell(arch, shape, mp, out_dir=args.out)
            except Exception as e:  # noqa: BLE001 — a failed cell is a bug
                traceback.print_exc()
                failures.append((arch, shape, mp, repr(e)))
    if failures:
        print(f"\n{len(failures)} FAILED cells:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print(f"\nall {len(cells) * len(meshes)} dry-run cells compiled OK")


if __name__ == "__main__":
    main()
