"""``input_specs`` — ShapeDtypeStruct stand-ins for every model input of an
(arch x shape) cell: weak-type-correct, shardable, zero allocation.

Conventions (DESIGN.md §4):
* train/prefill cells feed ``tokens (global_batch, seq_len)`` (+ frontend
  embeddings covering the first ``frontend_len`` positions for vlm/audio
  stubs; enc-dec feeds ``src_emb (B, seq_len, frontend_dim)`` to the encoder
  and targets of the same length to the decoder).
* decode cells feed one new token against a cache of ``seq_len`` (enc-dec:
  decoder self-cache of ``seq_len`` + a 4096-frame encoder memory).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig, ShapeConfig, shape_model_config
from repro.models import lm

SRC_LEN_DECODE = 4096  # encoder memory length for enc-dec decode cells


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        return {"tokens": sds((b, 1), jnp.int32)}
    out = {"tokens": sds((b, s), jnp.int32)}
    if cfg.family == "encdec":
        out["src_emb"] = sds((b, s, cfg.frontend_dim), jnp.float32)
    elif cfg.frontend != "none":
        out["frontend_emb"] = sds((b, cfg.frontend_len, cfg.frontend_dim),
                                  jnp.float32)
    return out


def cache_specs(cfg: ModelConfig, shape: ShapeConfig):
    assert shape.kind == "decode"
    return lm.cache_spec(cfg, shape.global_batch, shape.seq_len,
                         src_len=SRC_LEN_DECODE)


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(lambda k: lm.init_lm(k, cfg), jax.random.key(0))


def abstract_opt_state(params, opt_init):
    return jax.eval_shape(opt_init, params)


def choose_microbatch(cfg: ModelConfig, shape: ShapeConfig, mesh,
                      seq_shard: bool = False) -> int:
    """Gradient-accumulation factor so the per-device residual-stream scan
    carry stays under ~6 GB (v5e has 16 GB HBM; weights+opt take the rest).

    carry bytes/device = B_local * seq * d_model * 2 B * n_layers  (bf16,
    one saved carry per scanned layer under full remat).  Under sequence
    parallelism the carry is additionally sharded over the model axis, which
    usually removes the need for accumulation entirely."""
    if shape.kind != "train":
        return 0
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = sizes.get("data", 1) * sizes.get("pod", 1)
    b_local = max(1, shape.global_batch // dp)
    layers = cfg.n_layers + (cfg.enc_layers or 0)
    carry = b_local * shape.seq_len * cfg.d_model * 2 * layers
    if seq_shard:
        carry /= sizes.get("model", 1)
    budget = 6e9
    n = 1
    while carry / n > budget and n < b_local:
        n *= 2
    return n if n > 1 else 0
