"""Production training launcher.

Binds the mesh + logical sharding rules, builds the (optionally
microbatched) train step, places sharded parameters, and runs the train
loop with async checkpointing, restart-on-resume and straggler monitoring.

On real hardware::

    python -m repro.launch.train --arch qwen2-72b --shape train_4k \
        --multi-pod --steps 1000 --ckpt-dir /ckpts/qwen

On this CPU container use ``--smoke`` (reduced config, 1-device mesh) —
the code path (sharding, checkpointing, loop) is identical.
"""
import argparse
import os
import time

import jax
import jax.numpy as jnp

from repro.configs import (
    SHAPES, RunConfig, get_config, get_smoke_config, shape_model_config,
)
from repro.data import make_lm_iterator
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.launch.specs import choose_microbatch
from repro.models import lm
from repro.parallel import DEFAULT_RULES, axis_rules
from repro.parallel.specs import batch_shardings, param_shardings
from repro.train import CheckpointManager, StragglerMonitor, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k", choices=sorted(SHAPES))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config on a local mesh (CPU)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--batch", type=int, default=0, help="override batch")
    ap.add_argument("--seq", type=int, default=0, help="override seq")
    args = ap.parse_args()

    shape = SHAPES[args.shape]
    if args.smoke:
        cfg = get_smoke_config(args.arch)
        mesh = make_local_mesh(1, 1)
        batch_size = args.batch or 8
        seq = args.seq or 64
    else:
        cfg = shape_model_config(get_config(args.arch), shape)
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        batch_size = args.batch or shape.global_batch
        seq = args.seq or shape.seq_len

    rules = DEFAULT_RULES
    mb = choose_microbatch(cfg, shape, mesh) if not args.smoke else 0
    run = RunConfig(model=cfg, shape=shape, microbatch=mb)
    train_step, opt_init = make_train_step(run)

    with mesh, axis_rules(rules, mesh):
        params = lm.init_lm(jax.random.key(run.seed), cfg)
        p_shard = param_shardings(params, mesh, rules)
        params = jax.tree.map(jax.device_put, params, p_shard)
        opt = opt_init(params)
        step_fn = jax.jit(train_step, donate_argnums=(0, 1))

        nxt, ds = make_lm_iterator(batch=batch_size, seq=seq, vocab=cfg.vocab)
        mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
        start = 0
        if mgr and mgr.latest_step() is not None:
            st = mgr.restore({"params": params, "opt": opt, "data": ds},
                             shardings=None)
            params, opt, ds = st["params"], st["opt"], st["data"]
            start = mgr.latest_step()
            print(f"resumed from step {start}")

        mon = StragglerMonitor()
        for i in range(start, args.steps):
            batch, ds = nxt(ds)
            batch = jax.tree.map(
                lambda x: jax.device_put(x, batch_shardings(
                    {"tokens": x}, mesh, rules)["tokens"])
                if x.ndim == 2 else x, batch)
            mon.start()
            params, opt, metrics = step_fn(params, opt, batch)
            jax.block_until_ready(metrics["loss"])
            dt = mon.stop()
            if (i + 1) % 10 == 0 or i == start:
                print(f"step {i+1}: loss={float(metrics['loss']):.4f} "
                      f"gnorm={float(metrics['grad_norm']):.2f} {dt:.2f}s")
            if mgr and (i + 1) % args.ckpt_every == 0:
                mgr.save(i + 1, {"params": params, "opt": opt, "data": ds},
                         blocking=False)
        if mgr:
            mgr.wait()
        print("straggler report:", mon.report())


if __name__ == "__main__":
    main()
