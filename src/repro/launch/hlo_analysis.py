"""Compat shim: the HLO text parser moved to :mod:`repro.analysis.hlo_parser`
so the quantization-coverage auditor and the roofline dry-run share one
implementation.  Import from ``repro.analysis.hlo_parser`` in new code."""
from __future__ import annotations

from repro.analysis.hlo_parser import (
    Computation,
    analyze_hlo,
    computation_multipliers,
    split_computations,
)

__all__ = [
    "Computation",
    "analyze_hlo",
    "computation_multipliers",
    "split_computations",
]
