"""Roofline terms per (arch x shape x mesh) from the compiled dry-run.

    compute_s    = HLO_dot_FLOPs_per_device / peak_FLOPs
    memory_s     = HBM_bytes_per_device / HBM_bw
    collective_s = collective_wire_bytes_per_device / ICI_bw

Sources:
* **compute** — trip-corrected dot FLOPs parsed from the compiled HLO
  (hlo_analysis), i.e. what XLA actually scheduled (includes remat
  recompute); cross-checked against the analytic ``expected_hlo_flops``.
* **memory** — analytic per-device HBM traffic model (documented per term
  below).  XLA's ``bytes accessed`` is unusable here: while bodies are
  counted once and CPU fusion differs from TPU.
* **collective** — wire bytes parsed from the compiled HLO collectives,
  divided over the links of a chip (ICI is per-link; we charge the full
  per-device payload against one link — conservative).

Hardware constants (task brief, TPU v5e-class):
197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI.
"""
from __future__ import annotations

from typing import Any

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def _mesh_sizes(mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE); decode: D = B·1."""
    n = cfg.n_active_params() if cfg.family == "moe" else cfg.n_params()
    if shape.kind == "train":
        d = shape.global_batch * shape.seq_len
        return 6.0 * n * d
    if shape.kind == "prefill":
        d = shape.global_batch * shape.seq_len
        return 2.0 * n * d
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def expected_hlo_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Analytic estimate of *compiled* FLOPs: model flops x remat factor
    (full remat recomputes the forward once during backward: 8/6) plus the
    quantization ops are element-wise (not dot FLOPs)."""
    mf = model_flops(cfg, shape)
    if shape.kind == "train" and cfg.remat == "full":
        return mf * 8.0 / 6.0
    return mf


def hbm_bytes(cfg: ModelConfig, shape: ShapeConfig, mesh,
              microbatch: int = 0) -> float:
    """Per-device HBM traffic model (bytes / step).

    train : params{read fwd + read bwd-remat (bf16-equiv 2B each) + grad
            write fp32 + opt read/write (m[,v] + fp32 master) }
            + activations {residual carry write+read fwd, write+read bwd}
            + logits/embedding traffic
    decode: params read (2B) + KV/SSM cache read+write + small activations
    prefill: params read + activations + cache write
    """
    sizes = _mesh_sizes(mesh)
    n_dev = int(np.prod(list(sizes.values())))
    dp = sizes.get("data", 1) * sizes.get("pod", 1)
    param_shards = sizes.get("data", 1) * sizes.get("model", 1)  # FSDP x TP
    n = cfg.n_params()
    b_loc = max(1, shape.global_batch // dp)
    layers = cfg.n_layers + (cfg.enc_layers or 0)
    d = cfg.d_model

    if shape.kind == "train":
        # per param: 2B fwd read + 2B bwd read (bf16) + 4B grad write +
        # 8B fp32 master rw + 8B first-moment rw (second moment similar,
        # folded into the same budget for sgdm/adamw parity)
        params_bytes = n / param_shards * 24.0
        act = b_loc * shape.seq_len * d * 2  # one residual carry (bf16)
        act_bytes = act * layers * 4  # wr+rd fwd, wr+rd bwd (remat)
        logits = b_loc * shape.seq_len * cfg.vocab * 4 / sizes.get("model", 1)
        return params_bytes + act_bytes + 2 * logits
    if shape.kind == "prefill":
        params_bytes = n / param_shards * 2
        act_bytes = b_loc * shape.seq_len * d * 2 * layers * 2
        cache = _cache_bytes(cfg, shape, mesh)
        return params_bytes + act_bytes + cache
    # decode
    params_bytes = n / param_shards * 2
    cache = _cache_bytes(cfg, shape, mesh)
    return params_bytes + cache


def _cache_bytes(cfg: ModelConfig, shape: ShapeConfig, mesh) -> float:
    sizes = _mesh_sizes(mesh)
    n_dev = int(np.prod(list(sizes.values())))
    b = shape.global_batch
    if cfg.family in ("dense", "moe", "encdec"):
        per_tok = cfg.n_layers * 2 * cfg.n_kv_heads * cfg.hd * 2
        total = b * shape.seq_len * per_tok
    elif cfg.family == "ssm":
        total = b * cfg.n_layers * cfg.ssm_heads * cfg.ssm_headdim * cfg.ssm_state * 4 * 2
    else:  # hybrid: ssm states + windowed attn cache
        ssm = b * cfg.n_layers * cfg.ssm_heads * cfg.ssm_headdim * cfg.ssm_state * 4 * 2
        alen = min(shape.seq_len, cfg.window) if cfg.window else shape.seq_len
        attn = b * (cfg.n_layers // max(cfg.attn_every, 1)) * alen * \
            2 * cfg.n_kv_heads * cfg.hd * 2
        total = ssm + attn
    # decode reads the full cache once (+ small write); sharded over devices
    return total / n_dev * (1.0 if shape.kind == "decode" else 1.0)


def roofline_terms(cfg: ModelConfig, shape: ShapeConfig, mesh,
                   record: dict[str, Any]) -> dict[str, Any]:
    sizes = _mesh_sizes(mesh)
    n_dev = int(np.prod(list(sizes.values())))
    hlo_flops_dev = record["hlo"]["dot_flops"]
    coll_bytes_dev = record["hlo"]["coll_bytes"]
    mem_bytes_dev = hbm_bytes(cfg, shape, mesh, record.get("microbatch", 0))

    compute_s = hlo_flops_dev / PEAK_FLOPS
    memory_s = mem_bytes_dev / HBM_BW
    collective_s = coll_bytes_dev / ICI_BW
    terms = {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "hlo_flops_per_device": hlo_flops_dev,
        "hbm_bytes_per_device": mem_bytes_dev,
        "coll_bytes_per_device": coll_bytes_dev,
        "model_flops_total": model_flops(cfg, shape),
        "expected_hlo_flops_total": expected_hlo_flops(cfg, shape),
    }
    bound = max(terms["compute_s"], terms["memory_s"], terms["collective_s"])
    terms["bottleneck"] = (
        "compute" if bound == compute_s
        else "memory" if bound == memory_s
        else "collective"
    )
    # step time lower bound = max term (perfect overlap); roofline fraction =
    # the share of that bound the *useful* model flops could sustain.
    useful_s = terms["model_flops_total"] / n_dev / PEAK_FLOPS
    terms["step_lower_bound_s"] = bound
    terms["roofline_fraction"] = useful_s / bound if bound > 0 else 0.0
    terms["model_flops_ratio"] = (
        terms["model_flops_total"] / n_dev / hlo_flops_dev
        if hlo_flops_dev else 0.0
    )
    return terms
