"""glm4-9b — dense, GQA kv=2, half-rotary RoPE, QKV bias.
[hf:THUDM/glm-4-9b; hf]"""
from .base import ModelConfig

FULL = ModelConfig(
    name="glm4-9b", family="dense",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=2,
    d_ff=13696, vocab=151552, qkv_bias=True, rotary_pct=0.5,
)
