"""Config registry: the 10 assigned architectures (+ the paper's CNNs live
in ``repro.models.cnn``).  ``get_config(name)`` returns the full production
config; ``get_smoke_config(name)`` a reduced same-family config for CPU
smoke tests (small widths/depths/experts/vocab — the full configs are only
exercised via the dry-run's ShapeDtypeStructs)."""
from __future__ import annotations

import dataclasses

from .base import SHAPES, ModelConfig, RunConfig, ShapeConfig

from . import (  # noqa: E402
    chatglm3_6b,
    glm4_9b,
    llama4_scout_17b_a16e,
    mamba2_370m,
    moonshot_v1_16b_a3b,
    pixtral_12b,
    qwen2_72b,
    seamless_m4t_medium,
    yi_34b,
    zamba2_7b,
)

ARCHS = {
    m.FULL.name: m.FULL
    for m in (
        llama4_scout_17b_a16e, moonshot_v1_16b_a3b, mamba2_370m, yi_34b,
        chatglm3_6b, qwen2_72b, glm4_9b, pixtral_12b, seamless_m4t_medium,
        zamba2_7b,
    )
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def get_smoke_config(name: str) -> ModelConfig:
    """Reduced same-family config: runs one train/decode step on CPU."""
    cfg = get_config(name)
    kw = dict(
        n_layers=2, d_model=64, vocab=512,
        remat="none", compute_dtype="float32",
    )
    if cfg.n_heads:
        kw.update(n_heads=4, n_kv_heads=max(1, 4 * cfg.n_kv_heads // cfg.n_heads),
                  head_dim=16, d_ff=96)
    if cfg.family == "moe":
        kw.update(n_experts=4, top_k=min(cfg.top_k, 2), moe_d_ff=64)
    if cfg.family in ("ssm", "hybrid"):
        kw.update(ssm_state=16, ssm_headdim=16, ssm_chunk=16)
    if cfg.family == "hybrid":
        kw.update(n_layers=5, attn_every=2)
    if cfg.family == "encdec":
        kw.update(enc_layers=2)
    if cfg.frontend != "none":
        kw.update(frontend_dim=32, frontend_len=4)
    return dataclasses.replace(cfg, **kw)


def runnable_shapes(cfg: ModelConfig):
    """Which of the 4 assigned shapes run for this arch (DESIGN.md §4):
    ``long_500k`` only for sub-quadratic (ssm/hybrid) families."""
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        names.append("long_500k")
    return [SHAPES[n] for n in names]


def shape_model_config(cfg: ModelConfig, shape: ShapeConfig) -> ModelConfig:
    """Shape-dependent model tweaks (e.g. zamba2 long-context window)."""
    if shape.name == "long_500k" and cfg.family == "hybrid":
        return dataclasses.replace(cfg, window=4096)
    return cfg


__all__ = [
    "ARCHS", "SHAPES", "ModelConfig", "RunConfig", "ShapeConfig",
    "get_config", "get_smoke_config", "runnable_shapes", "shape_model_config",
]
