"""moonshot-v1-16b-a3b (Moonlight) — MoE 64 experts top-6 (DeepSeek-style
fine-grained experts).  [hf:moonshotai/Moonlight-16B-A3B; hf]"""
from .base import ModelConfig

FULL = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, moe_d_ff=1408, vocab=163840,
    n_experts=64, top_k=6,
    rope_theta=5e4,
)
