"""llama4-scout-17b-a16e — MoE, 16 routed experts top-1 + 1 shared expert,
early fusion (text backbone here; vision enters via frontend stubs on the
pixtral config instead).  [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from .base import ModelConfig

FULL = ModelConfig(
    name="llama4-scout-17b-a16e", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=8192, moe_d_ff=8192, vocab=202048,
    n_experts=16, top_k=1, n_shared_experts=1,
    rope_theta=5e5,
)
