"""pixtral-12b — pixtral-ViT frontend (STUB: input_specs feeds precomputed
patch embeddings) + mistral-nemo-style decoder backbone.
[hf:mistralai/Pixtral-12B-2409; unverified]"""
from .base import ModelConfig

FULL = ModelConfig(
    name="pixtral-12b", family="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=131072, rope_theta=1e9,
    frontend="vision", frontend_dim=1024, frontend_len=256,
)
