"""zamba2-7b — Mamba2 backbone + ONE shared attention+MLP block applied
every 6 layers (per-instance LoRA simplified to pure sharing, DESIGN.md §4).
[arXiv:2411.15242; unverified]"""
from .base import ModelConfig

FULL = ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
    d_ff=14336, vocab=32000, attn_every=6,
    ssm_state=64, ssm_expand=2, ssm_headdim=64, ssm_groups=1, ssm_conv=4,
    sub_quadratic=True,
)
