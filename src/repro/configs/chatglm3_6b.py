"""chatglm3-6b — dense, GQA kv=2, QKV bias, half-rotary (2d) RoPE.
[arXiv:2406.12793; hf]"""
from .base import ModelConfig

FULL = ModelConfig(
    name="chatglm3-6b", family="dense",
    n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2,
    d_ff=13696, vocab=65024, qkv_bias=True, rotary_pct=0.5,
)
