"""Model / run configuration schema for the LM-family architectures."""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core import EMFormat, FMT_IMAGENET, GS_FMT_DEFAULT, QuantConfig


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec
    n_layers: int
    d_model: int
    n_heads: int = 0
    n_kv_heads: int = 0
    d_ff: int = 0
    vocab: int = 32000
    head_dim: int = 0  # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rotary_pct: float = 1.0  # 0.5 = half-rotary (GLM family)
    rope_theta: float = 1e4
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    gated_mlp: bool = True  # SwiGLU-style
    tie_embeddings: bool = False
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0  # expert hidden size (d_ff used for dense/shared mlp)
    n_shared_experts: int = 0
    capacity_factor: float = 1.0
    # dispatch in (seq/chunks)-long row groups: sorts/scatters stay local
    # under sequence sharding (capacity is enforced per chunk)
    moe_dispatch_chunks: int = 1
    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_groups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 256
    # --- hybrid (Zamba2) ---
    attn_every: int = 0  # shared attention block every N layers (0 = off)
    # --- long-context ---
    window: int | None = None  # sliding window (long_500k mode for hybrid)
    sub_quadratic: bool = False  # True for ssm/hybrid: long_500k cell runs
    # --- enc-dec ---
    enc_layers: int = 0  # >0 -> encoder-decoder (seamless)
    # --- modality frontend stub ---
    frontend: str = "none"  # none | vision | audio
    frontend_dim: int = 0  # precomputed embedding dim fed by input_specs()
    frontend_len: int = 0  # number of frontend positions in the sequence
    # --- numerics ---
    quant: bool = True  # MLS low-bit training enabled (paper's technique)
    fmt: EMFormat = FMT_IMAGENET  # <2,4>: the paper's ImageNet-scale choice
    gs_fmt: EMFormat = GS_FMT_DEFAULT  # <8,1>
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: str = "full"  # full | dots | none
    # --- §Perf levers (beyond-paper; defaults = paper-faithful baseline) ---
    param_gather_dtype: str = "float32"  # bfloat16: halve FSDP gather bytes
    packed_wire: bool = False  # gather weights as packed MLS uint8 codes
    # Arithmetic backing the quantized GEMMs: "fake_quant" (XLA simulation)
    # or "pallas" (quantized-domain kernels) — see QuantConfig.backend.
    quant_backend: str = "fake_quant"

    # ------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def d_inner(self) -> int:  # ssm inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def qcfg(self) -> QuantConfig | None:
        if not self.quant:
            return None
        return QuantConfig(
            fmt=self.fmt, gs_fmt=self.gs_fmt, grouping="nc", k_block=128,
            stochastic=True, compute_dtype=jnp.dtype(self.compute_dtype),
            packed_wire=self.packed_wire, shard_ways=16,
            backend=self.quant_backend,
        )

    def n_params(self) -> int:
        """Total parameter count (for 6·N·D roofline math)."""
        d, hd = self.d_model, self.hd
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        if self.family in ("dense", "moe", "encdec"):
            attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
            if self.family == "moe":
                ff = 3 * d * self.moe_d_ff * (self.n_experts + self.n_shared_experts)
                ff += d * self.n_experts  # router
            else:
                mult = 3 if self.gated_mlp else 2
                ff = mult * d * self.d_ff
            per_layer = attn + ff
            n = per_layer * self.n_layers + emb
            if self.family == "encdec":
                # decoder adds cross-attention per layer
                n += self.enc_layers * (attn + (3 if self.gated_mlp else 2) * d * self.d_ff)
                n += self.enc_layers * attn  # cross-attn in decoder layers
            return n
        if self.family == "ssm":
            per = self._ssm_params()
            return per * self.n_layers + emb
        if self.family == "hybrid":
            per = self._ssm_params()
            attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
            shared = attn + 3 * d * self.d_ff
            return per * self.n_layers + shared + emb
        raise ValueError(self.family)

    def _ssm_params(self) -> int:
        d, din = self.d_model, self.d_inner
        g, n, h = self.ssm_groups, self.ssm_state, self.ssm_heads
        in_proj = d * (2 * din + 2 * g * n + h)
        conv = (din + 2 * g * n) * self.ssm_conv
        out = din * d
        return in_proj + conv + out + 3 * h  # + A_log, D, dt_bias

    def n_active_params(self) -> int:
        """Activated params per token (MoE discount) for 6·N_active·D."""
        if self.family != "moe":
            return self.n_params()
        d = self.d_model
        attn = d * self.hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * self.hd * d
        ff_active = 3 * d * self.moe_d_ff * (self.top_k + self.n_shared_experts)
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return (attn + ff_active + d * self.n_experts) * self.n_layers + emb


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One cell of the (arch x input-shape) matrix."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Training-run hyperparameters (the launcher consumes this)."""

    model: ModelConfig
    shape: ShapeConfig
    microbatch: int = 0  # 0 = no gradient accumulation
    optimizer: str = "adamw"
    lr: float = 3e-4
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    grad_compression: bool = False  # MLS-compressed cross-pod all-reduce
    seed: int = 0
