"""mamba2-370m — attention-free SSD (state-space duality).
[arXiv:2405.21060; unverified]"""
from .base import ModelConfig

FULL = ModelConfig(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, vocab=50280,
    ssm_state=128, ssm_expand=2, ssm_headdim=64, ssm_groups=1, ssm_conv=4,
    tie_embeddings=True, sub_quadratic=True,
)
