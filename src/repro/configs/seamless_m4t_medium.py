"""seamless-m4t-medium — encoder-decoder, audio frontend STUB (input_specs
feeds precomputed frame embeddings).  [arXiv:2308.11596; hf]"""
from .base import ModelConfig

FULL = ModelConfig(
    name="seamless-m4t-medium", family="encdec",
    n_layers=12, enc_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=256206, norm="layernorm", gated_mlp=False,
    frontend="audio", frontend_dim=512,
)
