"""MLS-compressed cross-pod gradient all-reduce (beyond-paper application of
the paper's format as a distributed-training compressor).

Within a pod, gradients all-reduce in full precision over fast ICI.  Across
pods the link is slow DCN, so each pod quantizes its pod-local gradient to
packed MLS codes (1 byte/element + one ``<8,1>`` scale per 128-group + one
fp32 scale/tensor ≈ **4x fewer wire bytes than fp32**, 2x vs bf16), exchanges
with ``collective_permute``, dequantizes and averages.  Stochastic rounding
keeps the compression unbiased (the same property the paper relies on for
SGD convergence, Sec. II-C).

For >2 pods the exchange generalizes to a ring of permutes (log or linear);
this module implements the 2-pod case used by the production mesh and the
generic ring for p pods.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import EMFormat, FMT_IMAGENET, GS_FMT_DEFAULT
from repro.core.quantize import GroupSpec, mls_quantize, pack_elements, unpack_elements


def _flatten_pad(g: jax.Array, block: int):
    flat = g.reshape(-1).astype(jnp.float32)
    pad = (-flat.shape[0]) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, block)


def compress(g: jax.Array, fmt: EMFormat = FMT_IMAGENET, block: int = 128,
             key: jax.Array | None = None):
    """-> (codes uint8 (n, block), s_g f32 (n, 1), s_t f32 scalar)."""
    rows = _flatten_pad(g, block)
    t = mls_quantize(rows, fmt, GroupSpec((1, block)), GS_FMT_DEFAULT, key)
    return pack_elements(t), t.s_g, t.s_t


def decompress(codes, s_g, s_t, shape, fmt: EMFormat = FMT_IMAGENET):
    sign, mag = unpack_elements(codes, fmt)
    vals = sign * mag * s_g * s_t
    return vals.reshape(-1)[: int(np.prod(shape))].reshape(shape)


def crosspod_allreduce_mean(g: jax.Array, axis_name: str = "pod",
                            fmt: EMFormat = FMT_IMAGENET,
                            key: jax.Array | None = None) -> jax.Array:
    """Mean over the pod axis exchanging MLS-compressed codes.

    Must run inside ``shard_map`` with ``axis_name`` bound.  Exact wire
    payload per hop: 1 B/elem codes + 4 B/128-elem group scales.
    """
    # jax < 0.6 has no lax.axis_size; psum of a literal 1 is the classic
    # idiom and stays static (resolved from the axis env at trace time)
    axis_size = getattr(jax.lax, "axis_size", None)
    p = int(axis_size(axis_name)) if axis_size is not None else int(
        jax.lax.psum(1, axis_name)
    )
    if p == 1:
        return g
    codes, s_g, s_t = compress(g, fmt, key=key)
    acc = g.astype(jnp.float32)
    perm_fwd = [(i, (i + 1) % p) for i in range(p)]
    my_codes, my_sg, my_st = codes, s_g, s_t
    for _ in range(p - 1):  # ring: p-1 hops of compressed payloads
        my_codes = jax.lax.ppermute(my_codes, axis_name, perm_fwd)
        my_sg = jax.lax.ppermute(my_sg, axis_name, perm_fwd)
        my_st = jax.lax.ppermute(my_st, axis_name, perm_fwd)
        acc = acc + decompress(my_codes, my_sg, my_st, g.shape, fmt)
    return (acc / p).astype(g.dtype)
