"""Parameter / input PartitionSpec inference.

Model code is mesh-agnostic; this module maps every parameter leaf to a
*logical* axis tuple by its tree path, then binds logical -> physical mesh
axes through :mod:`repro.parallel.sharding` rules, dropping any axis whose
size does not divide the dimension (GQA kv-head counts etc. stay replicated
rather than erroring).

The resulting layout is the standard 2-D "FSDP x TP" scheme:
parameters shard over ``data`` (FSDP) and ``model`` (TP/EP); the ``pod``
axis is pure DP — parameters are **replicated across pods** so the only
cross-pod traffic is the gradient all-reduce (optionally MLS-compressed).
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .sharding import AxisRules, DEFAULT_RULES, logical_to_mesh

# (path-substring, logical axes per trailing dim) — first match wins.
# Axes are aligned to the *trailing* dims; stacked layer dims get "stage".
_RULES: tuple[tuple[str, tuple[str | None, ...]], ...] = (
    ("emb", ("vocab", "fsdp")),
    ("lm_head", ("vocab", "fsdp")),
    ("frontend_proj", (None, "fsdp")),
    ("router", ("fsdp", None)),
    # MoE expert stacks (E, d, f) / (E, f, d)
    ("moe']['w_gate", ("expert", "fsdp", None)),
    ("moe']['w_up", ("expert", "fsdp", None)),
    ("moe']['w_down", ("expert", None, "fsdp")),
    ("wq']['b", ("heads",)),
    ("wk']['b", ("kv_heads",)),
    ("wv']['b", ("kv_heads",)),
    ("wo']['b", ("fsdp",)),
    ("wq", ("fsdp", "heads")),
    ("wk", ("fsdp", "kv_heads")),
    ("wv", ("fsdp", "kv_heads")),
    ("wo", ("heads", "fsdp")),
    ("w_gate", ("fsdp", "mlp")),
    ("w_up", ("fsdp", "mlp")),
    ("w_down", ("mlp", "fsdp")),
    ("in_proj", ("fsdp", "mlp")),
    ("out_proj", ("mlp", "fsdp")),
    ("conv_w", ("mlp", None)),
    ("conv_b", ("mlp",)),
    ("A_log", (None,)),
    ("dt_bias", (None,)),
)


def _mesh_axis_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    names = (entry,) if isinstance(entry, str) else entry
    return int(np.prod([dict(zip(mesh.axis_names, mesh.devices.shape))[n]
                        for n in names if n in mesh.axis_names] or [1]))


def logical_axes_for(path: str, ndim: int) -> tuple[str | None, ...]:
    for sub, axes in _RULES:
        if sub in path:
            n = len(axes)
            if ndim >= n:
                lead = ("stage",) + (None,) * (ndim - n - 1) if ndim > n else ()
                return tuple(lead) + axes
            return axes[-ndim:] if ndim else ()
    return (None,) * ndim  # norms, scalars, biases without rules: replicate


def spec_for(path: str, shape, mesh: Mesh, rules: AxisRules) -> P:
    logical = logical_axes_for(path, len(shape))
    entries = []
    for dim, name in zip(shape, logical):
        e = rules.get(name) if name else None
        size = _mesh_axis_size(mesh, e)
        if e is None or size <= 1 or dim % size != 0:
            entries.append(None)
        else:
            # prune axes missing from this mesh (pod vs single-pod reuse)
            if isinstance(e, tuple):
                e = tuple(a for a in e if a in mesh.axis_names) or None
            elif e not in mesh.axis_names:
                e = None
            entries.append(e)
    return P(*entries)


def param_shardings(tree: Any, mesh: Mesh, rules: AxisRules = DEFAULT_RULES):
    """Pytree of NamedShardings matching ``tree`` (arrays or SDS leaves)."""

    def f(path, leaf):
        p = jax.tree_util.keystr(path)
        return NamedSharding(mesh, spec_for(p, leaf.shape, mesh, rules))

    return jax.tree_util.tree_map_with_path(f, tree)


# ---------------------------------------------------------------------------
# input / cache shardings
# ---------------------------------------------------------------------------
_BATCH_AXES = {
    "tokens": ("batch", None),
    "frontend_emb": ("batch", None, None),
    "src_emb": ("batch", None, None),
    "image": ("batch", None, None, None),
    "label": ("batch",),
}

_CACHE_AXES = {
    "k": ("stage", "batch", "cache_seq", None, None),
    "v": ("stage", "batch", "cache_seq", None, None),
    "xk": ("stage", "batch", "cache_seq", None, None),
    "xv": ("stage", "batch", "cache_seq", None, None),
    "ak": ("stage", "batch", "cache_seq", None, None),
    "av": ("stage", "batch", "cache_seq", None, None),
    "conv": ("stage", "batch", None, "mlp"),
    "ssm": ("stage", "batch", "heads", None, None),
    "pos": (),
}


def _named(mesh, rules, logical, shape):
    entries = []
    for dim, name in zip(shape, logical):
        e = rules.get(name) if name else None
        size = _mesh_axis_size(mesh, e)
        if e is None or size <= 1 or dim % size != 0:
            entries.append(None)
        else:
            if isinstance(e, tuple):
                e = tuple(a for a in e if a in mesh.axis_names) or None
            elif e not in mesh.axis_names:
                e = None
            entries.append(e)
    return NamedSharding(mesh, P(*entries))


def _last_key(path) -> str:
    import re

    keys = re.findall(r"\['([^']+)'\]", jax.tree_util.keystr(path))
    return keys[-1] if keys else jax.tree_util.keystr(path)


def batch_shardings(batch: Any, mesh: Mesh, rules: AxisRules = DEFAULT_RULES):
    def f(path, leaf):
        key = _last_key(path)
        logical = _BATCH_AXES.get(key, ("batch",) + (None,) * (len(leaf.shape) - 1))
        return _named(mesh, rules, logical, leaf.shape)

    return jax.tree_util.tree_map_with_path(f, batch)


def cache_shardings(cache: Any, mesh: Mesh, rules: AxisRules = DEFAULT_RULES):
    def f(path, leaf):
        logical = _CACHE_AXES.get(_last_key(path), (None,) * len(leaf.shape))
        return _named(mesh, rules, logical, leaf.shape)

    return jax.tree_util.tree_map_with_path(f, cache)
