"""Logical-axis sharding: models annotate tensors with *logical* axis names;
the launcher binds logical names to physical mesh axes.

This is the MaxText/flax-linen "logical axis rules" pattern without the flax
dependency: model code stays mesh-agnostic, and dry-run/perf iterations can
re-bind rules (e.g. move "embed" from None to "model", or turn on sequence
sharding) without touching layer code.

Logical axes used by the model zoo:

    batch      — data-parallel batch dim            -> ("pod", "data")
    seq        — sequence (activation/SP sharding)  -> None (perf lever)
    embed      — residual stream d_model            -> None (or "model" for SP)
    heads      — attention heads                    -> "model"
    kv_heads   — kv heads (GQA)                     -> "model" when divisible
    mlp        — FFN hidden                          -> "model"
    vocab      — vocabulary                          -> "model"
    expert     — MoE experts                         -> "model"
    fsdp       — parameter shard dim (FSDP)          -> "data"
    stage      — layer-stack dim (scan-over-layers)  -> None
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


@dataclasses.dataclass(frozen=True)
class AxisRules:
    """Mapping logical axis name -> physical mesh axis (or None)."""

    rules: tuple[tuple[str, object | None], ...]

    def get(self, name: str):
        for k, v in self.rules:
            if k == name:
                return v
        return None

    def replace(self, **kw) -> "AxisRules":
        d = dict(self.rules)
        d.update(kw)
        return AxisRules(tuple(d.items()))


DEFAULT_RULES = AxisRules(
    rules=(
        ("batch", ("pod", "data")),
        ("seq", None),
        ("embed", None),
        ("heads", "model"),
        ("kv_heads", "model"),
        ("mlp", "model"),
        ("vocab", "model"),
        ("expert", "model"),
        ("fsdp", "data"),
        ("stage", None),
        ("cache_seq", "model"),  # decode KV caches shard over TP
        ("kv_seq", None),  # attention K/V seq dim: gathered under SP
        ("moe_rows", ("pod", "data")),  # MoE dispatch row groups
    )
)

# Sequence-parallel variant (§Perf): the residual stream / activations shard
# their sequence dim over the TP axis; attention K/V are gathered (cheap for
# GQA) while Q stays sequence-sharded.  Removes the gradient-accumulation
# requirement for the train_4k cells.
SP_RULES = DEFAULT_RULES.replace(
    seq="model", moe_rows=("pod", "data", "model")
)


def wire_pin(x: jax.Array, fsdp_dim: int) -> jax.Array:
    """Pin the weight gather onto *this* tensor (the packed uint8 codes or
    bf16 unit values) instead of somewhere upstream in the fp32 quantization
    math.

    Emits a (sharded, then gathered) constraint pair.  Under feature-TP
    rules only the FSDP dim is gathered (TP dims stay UNCONSTRAINED); under
    sequence-sharding rules (``seq`` mapped to a mesh axis) activations are
    row-sharded, so the weight must be gathered over *all* dims — which is
    exactly when moving 1-byte codes instead of 4-byte floats pays off most.
    """
    rules, mesh = current_rules(), _current_mesh()
    if rules is None or mesh is None:
        return x
    ax = _prune(mesh, rules.get("fsdp"))
    if ax is None or x.ndim <= fsdp_dim:
        return x
    if x.shape[fsdp_dim] % _axis_size(mesh, ax) != 0:
        return x
    seq_mode = _prune(mesh, rules.get("seq")) is not None
    U = P.UNCONSTRAINED
    sp1 = P(*[ax if i == fsdp_dim else U for i in range(x.ndim)])
    if seq_mode:  # gather every dim (activations are row-sharded)
        sp2 = P(*([None] * x.ndim))
    else:  # gather only the FSDP dim; TP dims stay as they are
        sp2 = P(*[None if i == fsdp_dim else U for i in range(x.ndim)])
    x = jax.lax.with_sharding_constraint(x, sp1)
    return jax.lax.with_sharding_constraint(x, sp2)


def current_rules() -> AxisRules | None:
    return getattr(_state, "rules", None)


def _current_mesh() -> Mesh | None:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def axis_rules(rules: AxisRules, mesh: Mesh | None = None):
    """Bind logical->physical rules (and optionally a mesh) for model code."""
    prev = (current_rules(), _current_mesh())
    _state.rules, _state.mesh = rules, mesh
    try:
        yield
    finally:
        _state.rules, _state.mesh = prev


def _prune(mesh: Mesh, spec_entry):
    """Drop mesh axes that don't exist in the bound mesh (single-pod vs
    multi-pod reuse the same rules)."""
    if spec_entry is None:
        return None
    if isinstance(spec_entry, str):
        return spec_entry if spec_entry in mesh.axis_names else None
    pruned = tuple(a for a in spec_entry if a in mesh.axis_names)
    return pruned if pruned else None


def logical_to_mesh(logical: tuple[str | None, ...],
                    rules: AxisRules | None = None,
                    mesh: Mesh | None = None) -> P:
    rules = rules or current_rules() or DEFAULT_RULES
    mesh = mesh or _current_mesh()
    entries = []
    for name in logical:
        e = rules.get(name) if name is not None else None
        if mesh is not None:
            e = _prune(mesh, e)
        entries.append(e)
    return P(*entries)


def _axis_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    names = (entry,) if isinstance(entry, str) else entry
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = 1
    for a in names:
        n *= sizes.get(a, 1)
    return n


def shard(x: jax.Array, *logical: str | None) -> jax.Array:
    """Annotate ``x`` with a sharding constraint from logical axis names.

    No-op when no rules are bound (unit tests, single-device smoke runs).
    Entries whose mesh-axis size does not divide the dimension are dropped —
    otherwise XLA falls back to "involuntary full rematerialization"
    (replicate + repartition), which wrecks the collective roofline term.
    """
    rules = current_rules()
    if rules is None:
        return x
    mesh = _current_mesh()
    spec = logical_to_mesh(logical, rules)
    if mesh is not None:
        entries, used = [], set()
        for dim, e in zip(x.shape, tuple(spec) + (None,) * (x.ndim - len(spec))):
            if e is not None:
                # drop mesh axes already used by an earlier dim (a rules
                # variant may map two logical axes to the same mesh axis,
                # e.g. seq->model + heads->model under sequence parallelism)
                names = (e,) if isinstance(e, str) else tuple(e)
                names = tuple(n for n in names if n not in used)
                e = (names[0] if len(names) == 1 else names) if names else None
            # drop only when dim < axis size (XLA pads non-divisible dims at
            # <= 2x waste; replication would cost the full axis factor)
            if e is not None and dim < _axis_size(mesh, e):
                e = None
            if e is not None:
                used.update((e,) if isinstance(e, str) else e)
            entries.append(e)
        spec = P(*entries)
    return jax.lax.with_sharding_constraint(x, spec)
