from .sharding import (
    DEFAULT_RULES,
    AxisRules,
    axis_rules,
    current_rules,
    logical_to_mesh,
    shard,
)

__all__ = [
    "DEFAULT_RULES", "AxisRules", "axis_rules", "current_rules",
    "logical_to_mesh", "shard",
]
