"""Mixture-of-Experts FFN with top-k routing and capacity-bounded dispatch.

Dispatch is computed **per batch row** (sort-based position assignment inside
each row) so the scatter/gather never crosses the data-parallel sharding of
the batch dimension; expert weights are sharded over the ``expert`` logical
axis (EP on the "model" mesh axis).  Tokens beyond an expert's capacity are
dropped (contribute zero), GShard-style.

Per the paper's layer-exemption policy the router runs in fp32 and is never
quantized; the expert GEMMs go through the MLS low-bit path (they dominate
the FLOPs — the best case for the paper's technique).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import QuantConfig, lowbit_matmul
from repro.parallel import shard
from . import nn

Array = jax.Array


def _fold(key, tag):
    return None if key is None else jax.random.fold_in(key, tag)


def init_moe(key, cfg: ModelConfig):
    ks = jax.random.split(key, 5)
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts

    def expert_stack(k, shape, fan_in, fan_out):
        keys = jax.random.split(k, e)
        return jax.vmap(lambda kk: nn.xavier(kk, shape, fan_in, fan_out))(keys)

    p = {
        "router": nn.init_linear(ks[0], d, e, False, std=0.02),
        "w_gate": expert_stack(ks[1], (d, f), d, f),
        "w_up": expert_stack(ks[2], (d, f), d, f),
        "w_down": expert_stack(ks[3], (f, d), f, d),
    }
    if cfg.n_shared_experts:
        from .transformer import init_mlp

        p["shared"] = init_mlp(
            ks[4], cfg, d_ff=cfg.moe_d_ff * cfg.n_shared_experts
        )
    return p


def _positions_in_runs(sorted_e: Array) -> Array:
    """For a sorted expert-id row, the index of each entry within its run."""
    t = sorted_e.shape[0]
    idx = jnp.arange(t)
    run_start = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_e[1:] != sorted_e[:-1]]
    )
    start_idx = jnp.where(run_start, idx, 0)
    start_idx = jax.lax.associative_scan(jnp.maximum, start_idx)
    return idx - start_idx


def apply_moe(p, x: Array, cfg: ModelConfig, qcfg: QuantConfig | None, key):
    """x: (B, S, d) -> (y, aux_loss).

    With ``cfg.moe_dispatch_chunks > 1`` the sequence is split into that many
    row groups and dispatch (sort/scatter/gather) runs per group — local
    under sequence sharding; capacity applies per group."""
    b0, s0, d0 = x.shape
    nc = cfg.moe_dispatch_chunks
    if nc > 1 and s0 % nc == 0:
        y, aux = _apply_moe_rows(
            p, x.reshape(b0 * nc, s0 // nc, d0), cfg, qcfg, key)
        return y.reshape(b0, s0, d0), aux
    return _apply_moe_rows(p, x, cfg, qcfg, key)


def _apply_moe_rows(p, x: Array, cfg: ModelConfig, qcfg: QuantConfig | None,
                    key):
    b, s, d = x.shape
    e, k, f = cfg.n_experts, cfg.top_k, cfg.moe_d_ff
    cap = int(s * k / e * cfg.capacity_factor + 1)

    # ---- routing (fp32, unquantized — paper's first/last-layer reasoning) --
    logits = nn.linear(p["router"], x.astype(jnp.float32), None)
    probs = jax.nn.softmax(logits, axis=-1)  # (B, S, E)
    topw, topi = jax.lax.top_k(probs, k)  # (B, S, k)
    topw = topw / jnp.sum(topw, axis=-1, keepdims=True)

    # load-balance aux loss (Switch/GShard form)
    me = jnp.mean(probs, axis=(0, 1))  # mean router prob per expert
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(topi, e, dtype=jnp.float32), axis=2), axis=(0, 1)
    ) / k
    aux = e * jnp.sum(me * ce)

    # ---- per-row dispatch ------------------------------------------------
    t = s * k
    e_flat = topi.reshape(b, t)
    w_flat = topw.reshape(b, t)

    order = jnp.argsort(e_flat, axis=1, stable=True)  # (B, T)
    se = jnp.take_along_axis(e_flat, order, axis=1)
    sw = jnp.take_along_axis(w_flat, order, axis=1)
    pos = jax.vmap(_positions_in_runs)(se)  # (B, T)
    tok = order // k  # source token of each dispatch slot

    def scatter_row(xrow, se_r, pos_r, tok_r):
        buf = jnp.zeros((e, cap, d), x.dtype)
        return buf.at[se_r, pos_r].set(xrow[tok_r], mode="drop")

    buf = jax.vmap(scatter_row)(x, se, pos, tok)  # (B, E, C, d)
    buf = shard(buf, "moe_rows", None, None, None)

    # ---- expert FFN (MLS-quantized GEMMs), batched over experts ----------
    xe = buf.transpose(1, 0, 2, 3).reshape(e, b * cap, d)

    def expert_ffn(xi, wg, wu, wd, ki):
        if qcfg is not None and qcfg.enabled:
            g = lowbit_matmul(xi, wg, _fold(ki, 0), qcfg)
            u = lowbit_matmul(xi, wu, _fold(ki, 1), qcfg)
            h = (jax.nn.silu(g) * u).astype(xi.dtype)
            return lowbit_matmul(h, wd, _fold(ki, 2), qcfg)
        g = xi @ wg.astype(xi.dtype)
        u = xi @ wu.astype(xi.dtype)
        h = (jax.nn.silu(g) * u).astype(xi.dtype)
        return h @ wd.astype(xi.dtype)

    if key is not None and qcfg is not None and qcfg.enabled and qcfg.stochastic:
        ekeys = jax.vmap(lambda i: jax.random.fold_in(key, i))(jnp.arange(e))
        ye = jax.vmap(expert_ffn)(xe, p["w_gate"], p["w_up"], p["w_down"], ekeys)
    else:
        ye = jax.vmap(lambda xi, wg, wu, wd: expert_ffn(xi, wg, wu, wd, None))(
            xe, p["w_gate"], p["w_up"], p["w_down"]
        )
    ye = ye.reshape(e, b, cap, d).transpose(1, 0, 2, 3)  # (B, E, C, d)
    ye = shard(ye, "moe_rows", None, None, None)

    # ---- gather back + combine -------------------------------------------
    def gather_row(buf_r, se_r, pos_r, sw_r, tok_r):
        vals = buf_r.at[se_r, pos_r].get(mode="fill", fill_value=0.0)  # (T, d)
        y = jnp.zeros((s, d), vals.dtype)
        return y.at[tok_r].add(vals * sw_r[:, None].astype(vals.dtype))

    y = jax.vmap(gather_row)(ye, se, pos, sw, tok)

    if "shared" in p:
        from .transformer import apply_mlp

        y = y + apply_mlp(p["shared"], x, cfg, qcfg, _fold(key, 9999))
    return y.astype(x.dtype), aux
