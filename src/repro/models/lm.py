"""Unified LM: init / train-loss / prefill / decode for all five families.

Families
--------
* ``dense``  — GQA transformer (yi-34b, chatglm3, qwen2, glm4, pixtral backbone)
* ``moe``    — GQA transformer with MoE FFN (llama4-scout, moonshot)
* ``ssm``    — Mamba2 / SSD stack (mamba2-370m)
* ``hybrid`` — Mamba2 backbone with a **shared** attention+MLP block applied
               every ``attn_every`` layers (zamba2-7b)
* ``encdec`` — encoder-decoder with cross attention (seamless-m4t)

Layers are stacked (vmap-init) and executed with ``lax.scan`` (+ remat), so
the lowered HLO is O(1) in depth — required for the 512-device dry-runs.
Every quantization site derives its stochastic-rounding stream from
``fold_in(step_key, layer_index)``; restart-reproducible (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import QuantConfig
from repro.parallel import shard
from . import nn
from .mamba2 import apply_mamba2, init_mamba2
from .moe import apply_moe, init_moe
from .transformer import (
    apply_attention,
    apply_block,
    apply_mlp,
    init_attention,
    init_block,
    init_mlp,
    norm_apply,
    norm_init,
)

Array = jax.Array


def _fold(key, tag):
    return None if key is None else jax.random.fold_in(key, tag)


def gather_view(p, cfg: ModelConfig):
    """Optionally cast layer parameters to the compute dtype *before* the
    layer scan, so FSDP all-gathers move 2-byte (bf16) rather than 4-byte
    weights (§Perf lever; fp32 masters stay in the optimizer).  The cast is
    element-wise on the shards, so XLA keeps it before the gather."""
    if cfg.param_gather_dtype == "float32":
        return p
    dt = jnp.dtype(cfg.param_gather_dtype)

    def cast(x):
        return x.astype(dt) if x.dtype == jnp.float32 else x

    out = dict(p)
    for k in ("layers", "enc_layers", "shared_attn"):
        if k in p:
            out[k] = jax.tree.map(cast, p[k])
    return out


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    return jax.checkpoint(fn)


# ===========================================================================
# init
# ===========================================================================
def _init_layer(key, cfg: ModelConfig, kind: str):
    if kind == "dense":
        return init_block(key, cfg)
    if kind == "moe":
        ka, km = jax.random.split(key)
        return {
            "ln1": norm_init(cfg),
            "attn": init_attention(ka, cfg),
            "ln2": norm_init(cfg),
            "moe": init_moe(km, cfg),
        }
    if kind == "ssm":
        return init_mamba2(key, cfg)
    if kind == "xdec":  # encoder-decoder decoder layer (self + cross + mlp)
        ka, kx, km = jax.random.split(key, 3)
        return {
            "ln1": norm_init(cfg),
            "attn": init_attention(ka, cfg),
            "lnx": norm_init(cfg),
            "xattn": init_attention(kx, cfg),
            "ln2": norm_init(cfg),
            "mlp": init_mlp(km, cfg),
        }
    raise ValueError(kind)


def _stack_init(key, cfg: ModelConfig, n: int, kind: str):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: _init_layer(k, cfg, kind))(keys)


def init_lm(key, cfg: ModelConfig):
    ks = iter(jax.random.split(key, 8))
    d = cfg.d_model
    p: dict[str, Any] = {
        "emb": nn.trunc_normal(next(ks), (cfg.vocab, d), std=0.02),
        "final_norm": norm_init(cfg),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = nn.trunc_normal(next(ks), (cfg.vocab, d), std=0.02)
    if cfg.frontend != "none":
        p["frontend_proj"] = nn.init_linear(next(ks), cfg.frontend_dim, d, True)
    fam = cfg.family
    if fam in ("dense", "moe"):
        p["layers"] = _stack_init(next(ks), cfg, cfg.n_layers, fam)
    elif fam == "ssm":
        p["layers"] = _stack_init(next(ks), cfg, cfg.n_layers, "ssm")
    elif fam == "hybrid":
        p["layers"] = _stack_init(next(ks), cfg, cfg.n_layers, "ssm")
        p["shared_attn"] = init_block(next(ks), cfg)  # ONE block, reused
    elif fam == "encdec":
        p["enc_layers"] = _stack_init(next(ks), cfg, cfg.enc_layers, "dense")
        p["layers"] = _stack_init(next(ks), cfg, cfg.n_layers, "xdec")
    else:
        raise ValueError(fam)
    return p


# ===========================================================================
# embedding / head
# ===========================================================================
def embed(p, batch: dict[str, Array], cfg: ModelConfig) -> Array:
    tokens = batch["tokens"]
    x = jnp.take(p["emb"], tokens, axis=0).astype(cfg.compute_dtype)
    if cfg.frontend != "none" and "frontend_emb" in batch:
        fe = nn.linear(p["frontend_proj"], batch["frontend_emb"].astype(
            cfg.compute_dtype))  # unquantized: "first layer" rule
        f = fe.shape[1]
        x = jnp.concatenate([fe.astype(x.dtype), x[:, f:]], axis=1)
    return shard(x, "batch", "seq", "embed")


def logits_fn(p, x, cfg: ModelConfig) -> Array:
    head = p["emb"] if cfg.tie_embeddings else p["lm_head"]
    # last layer unquantized (paper Sec. VI-A)
    out = jax.lax.dot_general(
        x, head.astype(x.dtype), (((x.ndim - 1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    return shard(out, "batch", "seq", "vocab")


# ===========================================================================
# family bodies (full-sequence: train / prefill)
# ===========================================================================
def _dense_scan(p, x, cfg, qcfg, key, *, caches=None, cache_pos=0,
                window=None, layer_kind="dense"):
    """Scan over stacked layers; optionally threading KV caches."""
    n = cfg.n_layers

    def body(carry, inp):
        x = carry
        lp, idx = inp["p"], inp["i"]
        lkey = _fold(key, idx)
        cache = (inp["ck"], inp["cv"]) if caches is not None else None
        if layer_kind == "moe":
            h, nc = apply_attention(
                lp["attn"], norm_apply(cfg, lp["ln1"], x), cfg, qcfg, lkey,
                cache=cache, cache_pos=cache_pos, window=window)
            x = shard(x + h.astype(x.dtype), "batch", "seq", "embed")
            h, aux = apply_moe(lp["moe"], norm_apply(cfg, lp["ln2"], x), cfg,
                               qcfg, _fold(lkey, 1000))
            x = shard(x + h.astype(x.dtype), "batch", "seq", "embed")
        else:
            x, nc = apply_block(lp, x, cfg, qcfg, lkey, cache=cache,
                                cache_pos=cache_pos, window=window)
            aux = jnp.float32(0.0)
        out = {"aux": aux}
        if caches is not None:
            out["ck"], out["cv"] = nc
        return x, out

    xs = {"p": p["layers"], "i": jnp.arange(n)}
    if caches is not None:
        xs["ck"], xs["cv"] = caches
    x, ys = jax.lax.scan(_remat(body, cfg), x, xs)
    new_caches = (ys["ck"], ys["cv"]) if caches is not None else None
    return x, jnp.mean(ys["aux"]), new_caches


def _ssm_scan(p, x, cfg, qcfg, key, *, states=None):
    n = cfg.n_layers

    def body(carry, inp):
        x = carry
        lp, idx = inp["p"], inp["i"]
        st = (inp["conv"], inp["ssm"]) if states is not None else None
        x, ns = apply_mamba2(lp, x, cfg, qcfg, _fold(key, idx), st)
        out = {}
        if states is not None:
            out["conv"], out["ssm"] = ns
        return x, out

    xs = {"p": p["layers"], "i": jnp.arange(n)}
    if states is not None:
        xs["conv"], xs["ssm"] = states
    x, ys = jax.lax.scan(_remat(body, cfg), x, xs)
    new_states = (ys["conv"], ys["ssm"]) if states is not None else None
    return x, jnp.float32(0.0), new_states


def _hybrid_apply(p, x, cfg, qcfg, key, *, states=None, attn_caches=None,
                  cache_pos=0, kv_valid=None, positions=None, window=None):
    """Zamba2: mamba scan segments with the shared attn block between them.

    Segment s covers layers [s*E, min((s+1)*E, L)); the shared block runs
    after every full segment of E layers (static python structure: ~14
    unrolled shared-block applications around scanned mamba segments).
    """
    e, L = cfg.attn_every, cfg.n_layers
    n_attn = L // e
    seg_bounds = []
    lo = 0
    for si in range(n_attn):
        seg_bounds.append((lo, lo + e, si))
        lo += e
    tail = (lo, L, None) if lo < L else None

    def seg_scan(x, lo, hi, st_slice):
        def body(carry, inp):
            x = carry
            st = (inp["conv"], inp["ssm"]) if states is not None else None
            x, ns = apply_mamba2(inp["p"], x, cfg, qcfg, _fold(key, inp["i"]), st)
            out = {}
            if states is not None:
                out["conv"], out["ssm"] = ns
            return x, out

        xs = {
            "p": jax.tree.map(lambda a: a[lo:hi], p["layers"]),
            "i": jnp.arange(lo, hi),
        }
        if states is not None:
            xs["conv"], xs["ssm"] = st_slice
        return jax.lax.scan(_remat(body, cfg), x, xs)

    new_conv, new_ssm, new_ck, new_cv = [], [], [], []
    for (lo, hi, si) in seg_bounds + ([tail] if tail else []):
        st_slice = None
        if states is not None:
            st_slice = (states[0][lo:hi], states[1][lo:hi])
        x, ys = seg_scan(x, lo, hi, st_slice)
        if states is not None:
            new_conv.append(ys["conv"])
            new_ssm.append(ys["ssm"])
        if si is not None:  # shared attention block after the segment
            cache = None
            if attn_caches is not None:
                cache = (attn_caches[0][si], attn_caches[1][si])
            x, nc = apply_block(
                p["shared_attn"], x, cfg, qcfg, _fold(key, 10_000 + si),
                cache=cache, cache_pos=cache_pos, kv_valid=kv_valid,
                positions=positions, window=window)
            if attn_caches is not None:
                new_ck.append(nc[0])
                new_cv.append(nc[1])
    new_states = None
    if states is not None:
        new_states = (jnp.concatenate(new_conv), jnp.concatenate(new_ssm))
    new_attn = None
    if attn_caches is not None:
        new_attn = (jnp.stack(new_ck), jnp.stack(new_cv))
    return x, jnp.float32(0.0), new_states, new_attn


def _encoder_apply(p, batch, cfg, qcfg, key):
    """Seamless encoder: bidirectional blocks over frontend embeddings."""
    fe = nn.linear(p["frontend_proj"], batch["src_emb"].astype(cfg.compute_dtype))
    x = shard(fe.astype(cfg.compute_dtype), "batch", "seq", "embed")

    def body(carry, inp):
        x, _ = apply_block(inp["p"], carry, cfg, qcfg, _fold(key, inp["i"]),
                           causal=False)
        return x, None

    x, _ = jax.lax.scan(
        _remat(body, cfg), x,
        {"p": p["enc_layers"], "i": jnp.arange(cfg.enc_layers) + 20_000},
    )
    return x


def _xdec_scan(p, x, cfg, qcfg, key, memory=None, *, caches=None,
               cross_kv=None, cache_pos=0):
    """Decoder scan with cross attention (memory = encoder output, or
    precomputed cross K/V caches during decode)."""

    def body(carry, inp):
        x = carry
        lp, idx = inp["p"], inp["i"]
        lkey = _fold(key, idx)
        cache = (inp["ck"], inp["cv"]) if caches is not None else None
        h, nc = apply_attention(
            lp["attn"], norm_apply(cfg, lp["ln1"], x), cfg, qcfg, lkey,
            cache=cache, cache_pos=cache_pos)
        x = shard(x + h.astype(x.dtype), "batch", "seq", "embed")
        # cross attention: recompute K/V from memory (train) or reuse caches
        if cross_kv is not None:
            h, _ = apply_attention(
                lp["xattn"], norm_apply(cfg, lp["lnx"], x), cfg, qcfg,
                _fold(lkey, 500), cross_cache=(inp["xk"], inp["xv"]))
        else:
            h, _ = apply_attention(
                lp["xattn"], norm_apply(cfg, lp["lnx"], x), cfg, qcfg,
                _fold(lkey, 500), kv=memory, causal=False)
        x = shard(x + h.astype(x.dtype), "batch", "seq", "embed")
        h = apply_mlp(lp["mlp"], norm_apply(cfg, lp["ln2"], x), cfg, qcfg, lkey)
        x = shard(x + h.astype(x.dtype), "batch", "seq", "embed")
        out = {}
        if caches is not None:
            out["ck"], out["cv"] = nc
        return x, out

    xs = {"p": p["layers"], "i": jnp.arange(cfg.n_layers)}
    if caches is not None:
        xs["ck"], xs["cv"] = caches
    if cross_kv is not None:
        xs["xk"], xs["xv"] = cross_kv
    x, ys = jax.lax.scan(_remat(body, cfg), x, xs)
    new_caches = (ys["ck"], ys["cv"]) if caches is not None else None
    return x, new_caches


# ===========================================================================
# train loss
# ===========================================================================
def lm_loss(p, batch: dict[str, Array], cfg: ModelConfig, key=None):
    """Causal (or seq2seq) LM loss. Returns (loss, metrics)."""
    qcfg = cfg.qcfg()
    p = gather_view(p, cfg)
    if cfg.family == "encdec":
        memory = _encoder_apply(p, batch, cfg, qcfg, _fold(key, 1))
        x = embed(p, batch, cfg)
        x, _ = _xdec_scan(p, x, cfg, qcfg, _fold(key, 2), memory)
        aux = jnp.float32(0.0)
    else:
        x = embed(p, batch, cfg)
        if cfg.family in ("dense", "moe"):
            x, aux, _ = _dense_scan(p, x, cfg, qcfg, _fold(key, 2),
                                    layer_kind=cfg.family)
        elif cfg.family == "ssm":
            x, aux, _ = _ssm_scan(p, x, cfg, qcfg, _fold(key, 2))
        else:  # hybrid
            x, aux, _, _ = _hybrid_apply(p, x, cfg, qcfg, _fold(key, 2))
    x = norm_apply(cfg, p["final_norm"], x)
    logits = logits_fn(p, x, cfg)

    tokens = batch["tokens"]
    targets = tokens[:, 1:]
    lg = logits[:, :-1].astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    ll = jnp.take_along_axis(lg, targets[..., None], axis=-1)[..., 0]
    mask = jnp.ones_like(targets, jnp.float32)
    if cfg.frontend != "none" and cfg.frontend_len and cfg.family != "encdec":
        # don't train on the frontend prefix positions
        mask = mask * (jnp.arange(targets.shape[1])[None, :] >= cfg.frontend_len)
    ce = jnp.sum((lse - ll) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    loss = ce + 0.01 * aux
    return loss, {"ce": ce, "aux": aux}


# ===========================================================================
# caches / serving
# ===========================================================================
def cache_spec(cfg: ModelConfig, batch: int, max_len: int, src_len: int = 4096):
    """ShapeDtypeStruct pytree of the decode cache (also used to allocate)."""
    dt = jnp.dtype(cfg.compute_dtype)
    hd, kv = cfg.hd, cfg.n_kv_heads
    L = cfg.n_layers

    def sd(shape, dtype=dt):
        return jax.ShapeDtypeStruct(shape, dtype)

    if cfg.family in ("dense", "moe"):
        return {
            "k": sd((L, batch, max_len, kv, hd)),
            "v": sd((L, batch, max_len, kv, hd)),
            "pos": sd((), jnp.int32),
        }
    if cfg.family == "ssm":
        return _ssm_cache_spec(cfg, batch, L, dt)
    if cfg.family == "hybrid":
        n_attn = L // cfg.attn_every
        alen = min(max_len, cfg.window) if cfg.window else max_len
        c = _ssm_cache_spec(cfg, batch, L, dt)
        c["ak"] = sd((n_attn, batch, alen, kv, hd))
        c["av"] = sd((n_attn, batch, alen, kv, hd))
        return c
    if cfg.family == "encdec":
        return {
            "k": sd((L, batch, max_len, kv, hd)),
            "v": sd((L, batch, max_len, kv, hd)),
            "xk": sd((L, batch, src_len, kv, hd)),
            "xv": sd((L, batch, src_len, kv, hd)),
            "pos": sd((), jnp.int32),
        }
    raise ValueError(cfg.family)


def _ssm_cache_spec(cfg, batch, L, dt):
    conv_dim = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    return {
        "conv": jax.ShapeDtypeStruct((L, batch, cfg.ssm_conv - 1, conv_dim), dt),
        "ssm": jax.ShapeDtypeStruct(
            (L, batch, cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state),
            jnp.float32,
        ),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def init_cache(cfg: ModelConfig, batch: int, max_len: int, src_len: int = 4096):
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype),
        cache_spec(cfg, batch, max_len, src_len),
    )


def decode_step(p, cache, tokens: Array, cfg: ModelConfig,
                memory: Array | None = None):
    """One serving step: ``tokens (B, 1)`` -> (logits (B, vocab), cache).

    No stochastic rounding at inference: nearest rounding (key=None).
    """
    qcfg = cfg.qcfg()
    if qcfg is not None:
        qcfg = dataclasses.replace(qcfg, stochastic=False)
    x = jnp.take(p["emb"], tokens, axis=0).astype(cfg.compute_dtype)
    x = shard(x, "batch", None, "embed")
    pos = cache["pos"]
    new_cache = dict(cache)
    if cfg.family in ("dense", "moe"):
        x, _, ncs = _dense_scan(
            p, x, cfg, qcfg, None, caches=(cache["k"], cache["v"]),
            cache_pos=pos, layer_kind=cfg.family)
        new_cache["k"], new_cache["v"] = ncs
    elif cfg.family == "ssm":
        x, _, nst = _ssm_scan(p, x, cfg, qcfg, None,
                              states=(cache["conv"], cache["ssm"]))
        new_cache["conv"], new_cache["ssm"] = nst
    elif cfg.family == "hybrid":
        alen = cache["ak"].shape[2]
        if cfg.window:  # ring buffer: write slot pos % alen, all slots valid
            wpos = pos % alen
            kv_valid = jnp.minimum(pos + 1, alen)
            positions = pos * jnp.ones((tokens.shape[0], 1), jnp.int32)
        else:
            wpos, kv_valid, positions = pos, None, None
        x, _, nst, nattn = _hybrid_apply(
            p, x, cfg, qcfg, None,
            states=(cache["conv"], cache["ssm"]),
            attn_caches=(cache["ak"], cache["av"]), cache_pos=wpos,
            kv_valid=kv_valid, positions=positions,
            window=None)  # the ring buffer already bounds the window
        new_cache["conv"], new_cache["ssm"] = nst
        new_cache["ak"], new_cache["av"] = nattn
    elif cfg.family == "encdec":
        x, ncs = _xdec_scan(
            p, x, cfg, qcfg, None, caches=(cache["k"], cache["v"]),
            cross_kv=(cache["xk"], cache["xv"]), cache_pos=pos)
        new_cache["k"], new_cache["v"] = ncs
    else:
        raise ValueError(cfg.family)
    new_cache["pos"] = pos + 1
    x = norm_apply(cfg, p["final_norm"], x)
    logits = logits_fn(p, x, cfg)[:, 0]
    return logits, new_cache


def prefill(p, batch: dict[str, Array], cfg: ModelConfig, max_len: int):
    """Run the full prompt, filling the cache; returns (logits_last, cache)."""
    qcfg = cfg.qcfg()
    if qcfg is not None:
        qcfg = dataclasses.replace(qcfg, stochastic=False)
    tokens = batch["tokens"]
    b, s = tokens.shape
    src_len = batch["src_emb"].shape[1] if "src_emb" in batch else 4096
    cache = init_cache(cfg, b, max_len, src_len)
    x = embed(p, batch, cfg)
    if cfg.family in ("dense", "moe"):
        x, _, ncs = _dense_scan(p, x, cfg, qcfg, None,
                                caches=(cache["k"], cache["v"]), cache_pos=0,
                                layer_kind=cfg.family)
        cache["k"], cache["v"] = ncs
    elif cfg.family == "ssm":
        x, _, nst = _ssm_scan(p, x, cfg, qcfg, None,
                              states=(cache["conv"], cache["ssm"]))
        cache["conv"], cache["ssm"] = nst
    elif cfg.family == "hybrid":
        x, _, nst, nattn = _hybrid_apply(
            p, x, cfg, qcfg, None, states=(cache["conv"], cache["ssm"]),
            attn_caches=(cache["ak"], cache["av"]), cache_pos=0)
        cache["conv"], cache["ssm"] = nst
        cache["ak"], cache["av"] = nattn
    else:  # encdec
        memory = _encoder_apply(p, batch, cfg, qcfg, None)
        # precompute cross K/V once per layer from the encoder output
        def xkv(lp, idx):
            hd = cfg.hd
            k = nn.linear(lp["xattn"]["wk"], memory, None).reshape(
                b, -1, cfg.n_kv_heads, hd)
            v = nn.linear(lp["xattn"]["wv"], memory, None).reshape(
                b, -1, cfg.n_kv_heads, hd)
            return k.astype(cache["xk"].dtype), v.astype(cache["xv"].dtype)

        ks, vs = jax.vmap(xkv, in_axes=(0, 0))(p["layers"], jnp.arange(cfg.n_layers))
        cache["xk"], cache["xv"] = ks, vs
        x, ncs = _xdec_scan(p, x, cfg, qcfg, None,
                            caches=(cache["k"], cache["v"]),
                            cross_kv=(cache["xk"], cache["xv"]), cache_pos=0)
        cache["k"], cache["v"] = ncs
    cache["pos"] = jnp.int32(s)
    x = norm_apply(cfg, p["final_norm"], x[:, -1:])
    logits = logits_fn(p, x, cfg)[:, 0]
    return logits, cache
