"""The paper's CNN model zoo: ResNet-20/18/34, VGG-16, GoogleNet.

Every quantizable conv/FC takes the layer's :class:`QuantConfig`; per the
paper (Sec. VI-A) the **first conv and the final classifier stay
unquantized**.  BN runs in fp32.  A ``width_mult``/``depth`` knob produces
the reduced smoke/training configs used on CPU.
"""
from __future__ import annotations

import dataclasses
from collections.abc import Sequence

import jax
import jax.numpy as jnp

from repro.core import QuantConfig
from . import nn

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    arch: str  # resnet20 | resnet18 | resnet34 | vgg16 | googlenet
    num_classes: int = 10
    width_mult: float = 1.0
    in_hw: int = 32  # 32 for CIFAR, 224 for ImageNet variants
    in_ch: int = 3

    def scaled(self, c: int) -> int:
        return max(4, int(round(c * self.width_mult)))


def _key_iter(key):
    while True:
        key, sub = jax.random.split(key)
        yield sub


def _fold(key, tag: int):
    return None if key is None else jax.random.fold_in(key, tag)


# ---------------------------------------------------------------------------
# ResNet (CIFAR basic-block and ImageNet basic-block variants)
# ---------------------------------------------------------------------------
def _init_block(ks, c_in, c_out, stride):
    p = {
        "conv1": nn.init_conv(next(ks), c_in, c_out, 3),
        "bn1": nn.init_batchnorm(c_out),
        "conv2": nn.init_conv(next(ks), c_out, c_out, 3),
        "bn2": nn.init_batchnorm(c_out),
    }
    if stride != 1 or c_in != c_out:
        p["proj"] = nn.init_conv(next(ks), c_in, c_out, 1)
        p["bn_proj"] = nn.init_batchnorm(c_out)
    return p


def _block(p, x, stride, qcfg, key, tag):
    h = nn.conv2d(p["conv1"], x, stride, "SAME", qcfg, _fold(key, tag))
    h = jax.nn.relu(nn.batchnorm(p["bn1"], h))
    h = nn.conv2d(p["conv2"], h, 1, "SAME", qcfg, _fold(key, tag + 1))
    h = nn.batchnorm(p["bn2"], h)
    if "proj" in p:
        x = nn.batchnorm(
            p["bn_proj"],
            nn.conv2d(p["proj"], x, stride, "SAME", qcfg, _fold(key, tag + 2)),
        )
    return jax.nn.relu(nn.ew_add(h, x))


_RESNET_STAGES = {
    "resnet20": ([3, 3, 3], [16, 32, 64], False),
    "resnet18": ([2, 2, 2, 2], [64, 128, 256, 512], True),
    "resnet34": ([3, 4, 6, 3], [64, 128, 256, 512], True),
}


def init_resnet(key, cfg: CNNConfig):
    ks = _key_iter(key)
    depths, widths, imagenet_stem = _RESNET_STAGES[cfg.arch]
    widths = [cfg.scaled(w) for w in widths]
    p = {}
    if imagenet_stem:
        p["stem"] = nn.init_conv(next(ks), cfg.in_ch, widths[0], 7)
    else:
        p["stem"] = nn.init_conv(next(ks), cfg.in_ch, widths[0], 3)
    p["bn_stem"] = nn.init_batchnorm(widths[0])
    c_in = widths[0]
    blocks = []
    for si, (d, w) in enumerate(zip(depths, widths)):
        for bi in range(d):
            stride = 2 if (bi == 0 and si > 0) else 1
            blocks.append(_init_block(ks, c_in, w, stride))
            c_in = w
    p["blocks"] = blocks
    p["fc"] = nn.init_linear(next(ks), c_in, cfg.num_classes, bias=True)
    return p


def apply_resnet(p, x, cfg: CNNConfig, qcfg: QuantConfig | None, key=None):
    depths, widths, imagenet_stem = _RESNET_STAGES[cfg.arch]
    # first layer unquantized (paper Sec. VI-A)
    h = nn.conv2d(p["stem"], x, 2 if imagenet_stem else 1, "SAME", None)
    h = jax.nn.relu(nn.batchnorm(p["bn_stem"], h))
    if imagenet_stem:
        h = jax.lax.reduce_window(
            h, -jnp.inf, jax.lax.max, (1, 1, 3, 3), (1, 1, 2, 2), "SAME"
        )
    bi_flat, tag = 0, 0
    for si, d in enumerate(depths):
        for bi in range(d):
            stride = 2 if (bi == 0 and si > 0) else 1
            h = _block(p["blocks"][bi_flat], h, stride, qcfg, key, tag)
            bi_flat += 1
            tag += 3
    h = jnp.mean(h, axis=(2, 3))
    return nn.linear(p["fc"], h, None)  # last layer unquantized


# ---------------------------------------------------------------------------
# VGG-16
# ---------------------------------------------------------------------------
_VGG16 = [64, 64, "M", 128, 128, "M", 256, 256, 256, "M",
          512, 512, 512, "M", 512, 512, 512, "M"]


def init_vgg16(key, cfg: CNNConfig):
    ks = _key_iter(key)
    p, c_in, convs = {}, cfg.in_ch, []
    for v in _VGG16:
        if v == "M":
            continue
        c = cfg.scaled(v)
        convs.append({"conv": nn.init_conv(next(ks), c_in, c, 3),
                      "bn": nn.init_batchnorm(c)})
        c_in = c
    p["convs"] = convs
    p["fc"] = nn.init_linear(next(ks), c_in, cfg.num_classes, bias=True)
    return p


def apply_vgg16(p, x, cfg: CNNConfig, qcfg: QuantConfig | None, key=None):
    h, ci, tag = x, 0, 0
    for v in _VGG16:
        if v == "M":
            h = jax.lax.reduce_window(
                h, -jnp.inf, jax.lax.max, (1, 1, 2, 2), (1, 1, 2, 2), "VALID"
            )
            continue
        q = None if ci == 0 else qcfg  # first conv unquantized
        blk = p["convs"][ci]
        h = jax.nn.relu(nn.batchnorm(blk["bn"], nn.conv2d(
            blk["conv"], h, 1, "SAME", q, _fold(key, tag))))
        ci += 1
        tag += 1
    h = jnp.mean(h, axis=(2, 3))
    return nn.linear(p["fc"], h, None)


# ---------------------------------------------------------------------------
# GoogleNet (Inception v1, BN variant, no aux heads)
# ---------------------------------------------------------------------------
# (1x1, (3x3red, 3x3), (5x5red, 5x5), pool_proj)
_INCEPTION = [
    ("3a", 64, (96, 128), (16, 32), 32),
    ("3b", 128, (128, 192), (32, 96), 64),
    ("M", 0, (0, 0), (0, 0), 0),
    ("4a", 192, (96, 208), (16, 48), 64),
    ("4b", 160, (112, 224), (24, 64), 64),
    ("4c", 128, (128, 256), (24, 64), 64),
    ("4d", 112, (144, 288), (32, 64), 64),
    ("4e", 256, (160, 320), (32, 128), 128),
    ("M", 0, (0, 0), (0, 0), 0),
    ("5a", 256, (160, 320), (32, 128), 128),
    ("5b", 384, (192, 384), (48, 128), 128),
]


def _init_inception(ks, c_in, cfg: CNNConfig, spec):
    _, c1, (c3r, c3), (c5r, c5), cp = spec
    s = cfg.scaled
    return {
        "b1": {"conv": nn.init_conv(next(ks), c_in, s(c1), 1), "bn": nn.init_batchnorm(s(c1))},
        "b3r": {"conv": nn.init_conv(next(ks), c_in, s(c3r), 1), "bn": nn.init_batchnorm(s(c3r))},
        "b3": {"conv": nn.init_conv(next(ks), s(c3r), s(c3), 3), "bn": nn.init_batchnorm(s(c3))},
        "b5r": {"conv": nn.init_conv(next(ks), c_in, s(c5r), 1), "bn": nn.init_batchnorm(s(c5r))},
        "b5": {"conv": nn.init_conv(next(ks), s(c5r), s(c5), 5), "bn": nn.init_batchnorm(s(c5))},
        "bp": {"conv": nn.init_conv(next(ks), c_in, s(cp), 1), "bn": nn.init_batchnorm(s(cp))},
    }


def _cbr(blk, x, k, stride, qcfg, key, tag):
    return jax.nn.relu(nn.batchnorm(blk["bn"], nn.conv2d(
        blk["conv"], x, stride, "SAME", qcfg, _fold(key, tag))))


def _inception(p, x, qcfg, key, tag):
    b1 = _cbr(p["b1"], x, 1, 1, qcfg, key, tag)
    b3 = _cbr(p["b3"], _cbr(p["b3r"], x, 1, 1, qcfg, key, tag + 1), 3, 1, qcfg, key, tag + 2)
    b5 = _cbr(p["b5"], _cbr(p["b5r"], x, 1, 1, qcfg, key, tag + 3), 5, 1, qcfg, key, tag + 4)
    pool = jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 1, 3, 3), (1, 1, 1, 1), "SAME")
    bp = _cbr(p["bp"], pool, 1, 1, qcfg, key, tag + 5)
    return jnp.concatenate([b1, b3, b5, bp], axis=1)


def init_googlenet(key, cfg: CNNConfig):
    ks = _key_iter(key)
    s = cfg.scaled
    p = {
        "stem1": {"conv": nn.init_conv(next(ks), cfg.in_ch, s(64), 7), "bn": nn.init_batchnorm(s(64))},
        "stem2": {"conv": nn.init_conv(next(ks), s(64), s(64), 1), "bn": nn.init_batchnorm(s(64))},
        "stem3": {"conv": nn.init_conv(next(ks), s(64), s(192), 3), "bn": nn.init_batchnorm(s(192))},
    }
    c_in, mods = s(192), []
    for spec in _INCEPTION:
        if spec[0] == "M":
            mods.append(None)
            continue
        mods.append(_init_inception(ks, c_in, cfg, spec))
        _, c1, (_, c3), (_, c5), cp = spec
        c_in = s(c1) + s(c3) + s(c5) + s(cp)
    p["inception"] = [m for m in mods if m is not None]
    p["fc"] = nn.init_linear(next(ks), c_in, cfg.num_classes, bias=True)
    return p


def apply_googlenet(p, x, cfg: CNNConfig, qcfg: QuantConfig | None, key=None):
    imagenet = cfg.in_hw >= 128
    h = _cbr(p["stem1"], x, 7, 2 if imagenet else 1, None, None, 0)  # unquantized
    if imagenet:
        h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max, (1, 1, 3, 3), (1, 1, 2, 2), "SAME")
    h = _cbr(p["stem2"], h, 1, 1, qcfg, key, 1)
    h = _cbr(p["stem3"], h, 3, 1, qcfg, key, 2)
    if imagenet:
        h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max, (1, 1, 3, 3), (1, 1, 2, 2), "SAME")
    mi, tag = 0, 10
    for spec in _INCEPTION:
        if spec[0] == "M":
            h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max, (1, 1, 3, 3), (1, 1, 2, 2), "SAME")
            continue
        h = _inception(p["inception"][mi], h, qcfg, key, tag)
        mi += 1
        tag += 6
    h = jnp.mean(h, axis=(2, 3))
    return nn.linear(p["fc"], h, None)


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------
def init_cnn(key, cfg: CNNConfig):
    if cfg.arch.startswith("resnet"):
        return init_resnet(key, cfg)
    if cfg.arch == "vgg16":
        return init_vgg16(key, cfg)
    if cfg.arch == "googlenet":
        return init_googlenet(key, cfg)
    raise ValueError(cfg.arch)


def apply_cnn(p, x, cfg: CNNConfig, qcfg: QuantConfig | None = None, key=None):
    if cfg.arch.startswith("resnet"):
        return apply_resnet(p, x, cfg, qcfg, key)
    if cfg.arch == "vgg16":
        return apply_vgg16(p, x, cfg, qcfg, key)
    if cfg.arch == "googlenet":
        return apply_googlenet(p, x, cfg, qcfg, key)
    raise ValueError(cfg.arch)


def count_ops(cfg: CNNConfig, batch: int = 1):
    """Exact op counts via shape tracing (paper Table I methodology)."""
    with nn.OpTrace() as tr:
        def run(x):
            p = jax.eval_shape(lambda k: init_cnn(k, cfg), jax.random.key(0))
            p = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), p)
            return apply_cnn(p, x, cfg)
        jax.eval_shape(run, jax.ShapeDtypeStruct((batch, cfg.in_ch, cfg.in_hw, cfg.in_hw), jnp.float32))
    return tr.ops
