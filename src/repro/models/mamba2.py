"""Mamba2 block: state-space duality (SSD) chunked algorithm [arXiv:2405.21060].

TPU adaptation notes (DESIGN.md §3): the chunked SSD turns the recurrence
into dense GEMMs (intra-chunk "attention-like" matmuls + small inter-chunk
scan) — exactly the MXU-friendly form.  The in/out projections (≈90% of the
FLOPs) run through the paper's MLS low-bit path; the decay/recurrence math
stays fp32 (cumulative products of ``exp(A·dt)`` need the dynamic range the
paper reserves for its fp32-exempt ops — see DESIGN.md §Arch-applicability).

Decode is O(1) per token: a single recurrent state update per layer.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import QuantConfig
from repro.parallel import shard
from . import nn

Array = jax.Array


def _fold(key, tag):
    return None if key is None else jax.random.fold_in(key, tag)


def init_mamba2(key, cfg: ModelConfig):
    ks = jax.random.split(key, 5)
    d, din = cfg.d_model, cfg.d_inner
    g, n, h = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    conv_dim = din + 2 * g * n
    return {
        "ln": nn.init_rmsnorm(d),
        "in_proj": nn.init_linear(ks[0], d, 2 * din + 2 * g * n + h, std=0.02),
        "conv_w": nn.trunc_normal(ks[1], (conv_dim, cfg.ssm_conv), std=0.2),
        "conv_b": jnp.zeros((conv_dim,)),
        "A_log": jnp.log(
            jax.random.uniform(ks[2], (h,), jnp.float32, 1.0, 16.0)
        ),
        "D": jnp.ones((h,)),
        "dt_bias": jnp.log(
            jnp.exp(jax.random.uniform(ks[3], (h,), jnp.float32, 1e-3, 0.1)) - 1.0
        ),
        "out_norm": nn.init_rmsnorm(din),
        "out_proj": nn.init_linear(ks[4], din, d, std=0.02),
    }


def _segsum(a: Array) -> Array:
    """a: (..., q) -> L (..., q, q) with L[i, j] = sum_{j < t <= i} a[t],
    -inf above the diagonal (the SSD 1-semiseparable mask)."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool), 0)
    return jnp.where(mask, seg, -jnp.inf)


def ssd_chunked(
    x: Array,  # (B, S, H, P) inputs (already dt-scaled by caller)
    a: Array,  # (B, S, H)    log decays (negative), already dt-scaled
    bm: Array,  # (B, S, G, N)
    cm: Array,  # (B, S, G, N)
    chunk: int,
    init_state: Array | None = None,  # (B, H, P, N)
) -> tuple[Array, Array]:
    """Returns (y (B,S,H,P), final_state (B,H,P,N)). fp32 internal math."""
    b, s, h, p = x.shape
    g, n = bm.shape[2], bm.shape[3]
    assert s % chunk == 0, (s, chunk)
    nc, q = s // chunk, chunk
    rep = h // g
    x = x.astype(jnp.float32).reshape(b, nc, q, h, p)
    a = a.astype(jnp.float32).reshape(b, nc, q, h)
    bm = bm.astype(jnp.float32).reshape(b, nc, q, g, n)
    cm = cm.astype(jnp.float32).reshape(b, nc, q, g, n)
    # broadcast kv-style groups over heads
    bmh = jnp.repeat(bm, rep, axis=3)  # (b, nc, q, h, n)
    cmh = jnp.repeat(cm, rep, axis=3)

    a_cs = jnp.cumsum(a, axis=2)  # (b, nc, q, h)

    # --- intra-chunk (diagonal blocks): (C B^T ⊙ L) x ----------------------
    L = jnp.exp(_segsum(a.transpose(0, 1, 3, 2)))  # (b, nc, h, q, q)
    cb = jnp.einsum("bclhn,bcshn->bchls", cmh, bmh)
    y_diag = jnp.einsum("bchls,bcshp->bclhp", cb * L, x)

    # --- chunk states: contribution of each chunk to its final state -------
    decay_states = jnp.exp(a_cs[:, :, -1:, :] - a_cs)  # (b, nc, q, h)
    states = jnp.einsum("bcshn,bcsh,bcshp->bchpn", bmh, decay_states, x)

    # --- inter-chunk recurrence (tiny scan over nc) -------------------------
    chunk_decay = jnp.exp(a_cs[:, :, -1, :])  # (b, nc, h)
    s0 = (
        jnp.zeros((b, h, p, n), jnp.float32)
        if init_state is None
        else init_state.astype(jnp.float32)
    )

    def step(carry, inp):
        st_c, dec_c = inp  # (b,h,p,n), (b,h)
        new = st_c + dec_c[:, :, None, None] * carry
        return new, carry  # emit state BEFORE this chunk

    final_state, prev_states = jax.lax.scan(
        step, s0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (b, nc, h, p, n)

    # --- off-diagonal: carry-in state read by each position -----------------
    state_decay = jnp.exp(a_cs)  # (b, nc, q, h)
    y_off = jnp.einsum(
        "bclhn,bchpn,bclh->bclhp", cmh, prev_states, state_decay
    )
    y = (y_diag + y_off).reshape(b, s, h, p)
    return y, final_state


def _causal_conv(x: Array, w: Array, b: Array, state: Array | None = None):
    """Depthwise causal conv over the sequence. x: (B, S, C); w: (C, K).

    With ``state`` (B, K-1, C) given (decode), prepends it; returns
    (y, new_state)."""
    k = w.shape[1]
    if state is not None:
        xin = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    else:
        xin = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    y = _depthwise(xin, w) + b
    new_state = xin[:, -(k - 1):, :] if k > 1 else None
    return y, new_state


def _depthwise(x: Array, w: Array) -> Array:
    """x: (B, T, C), w: (C, K) causal valid conv -> (B, T-K+1, C)."""
    k = w.shape[1]
    t = x.shape[1] - k + 1
    out = jnp.zeros(x.shape[:1] + (t,) + x.shape[2:], jnp.float32)
    for i in range(k):  # K is 4: unrolled taps vectorize cleanly
        out = out + x[:, i : i + t, :].astype(jnp.float32) * w[:, i]
    return out


def apply_mamba2(
    p,
    x: Array,  # (B, S, d)
    cfg: ModelConfig,
    qcfg: QuantConfig | None,
    key,
    state: tuple[Array, Array] | None = None,  # (conv_state, ssm_state)
):
    """Full-sequence (train/prefill) or stateful (decode) Mamba2 block.

    Returns (y, new_state); new_state is None unless ``state`` was given or
    S == 1 (decode)."""
    b, s, d = x.shape
    din, g, n, h = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    pdim = cfg.ssm_headdim
    res = x
    xn = nn.rmsnorm(p["ln"], x)
    zxbcdt = nn.linear(p["in_proj"], xn, qcfg, _fold(key, 0), wire=0)
    z, xbc, dt = jnp.split(zxbcdt, [din, 2 * din + 2 * g * n], axis=-1)
    conv_state = state[0] if state is not None else None
    xbc, new_conv_state = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xbc = jax.nn.silu(xbc)
    xin, bm, cm = jnp.split(xbc, [din, din + g * n], axis=-1)
    xin = shard(xin.reshape(b, s, h, pdim), "batch", "seq", "heads", None)
    bm = bm.reshape(b, s, g, n)
    cm = cm.reshape(b, s, g, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    a = -jnp.exp(p["A_log"])  # (H,)

    xdt = xin.astype(jnp.float32) * dt[..., None]
    adt = a * dt  # (B, S, H) negative

    ssm_state = state[1] if state is not None else None
    if s == 1 and state is not None:
        # O(1) decode: state = exp(a dt) * state + B ⊗ x dt ; y = C · state
        dA = jnp.exp(adt[:, 0])  # (B, H)
        bmh = jnp.repeat(bm[:, 0], h // g, axis=1)  # (B, H, N)
        cmh = jnp.repeat(cm[:, 0], h // g, axis=1)
        new_ssm = dA[:, :, None, None] * ssm_state + jnp.einsum(
            "bhn,bhp->bhpn", bmh, xdt[:, 0]
        )
        y = jnp.einsum("bhpn,bhn->bhp", new_ssm, cmh)[:, None]  # (B,1,H,P)
    else:
        chunk = min(cfg.ssm_chunk, s)
        while s % chunk:  # largest divisor of s not above ssm_chunk
            chunk -= 1
        y, new_ssm = ssd_chunked(xdt, adt, bm, cm, chunk, ssm_state)

    y = y + p["D"][:, None] * xin.astype(jnp.float32)
    y = y.reshape(b, s, din)
    y = nn.rmsnorm(p["out_norm"], y * jax.nn.silu(z.astype(jnp.float32)))
    out = nn.linear(p["out_proj"], y.astype(x.dtype), qcfg, _fold(key, 1), wire=1)
    new_state = None
    if state is not None or s == 1:
        new_state = (new_conv_state, new_ssm)
    return res + out.astype(x.dtype), new_state
