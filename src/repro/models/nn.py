"""Minimal functional NN library (no flax dependency).

Parameters are plain pytrees of ``jnp`` arrays; every layer is an
``init_*(key, ...) -> params`` / ``apply(params, x, ...) -> y`` pair.  Layers
that contain a GEMM/conv take an optional :class:`repro.core.QuantConfig`;
when given (and enabled) the op runs through the paper's low-bit training
path (quantized W/A/E with STE), otherwise through a plain fp32/bf16 op.

Stochastic-rounding keys: callers pass one per-step key; layers fold in a
stable integer tag so every quantization site gets an independent stream
(the paper generates its U[-1/2,1/2) tensors offline — same semantics).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import QuantConfig, lowbit_conv, lowbit_matmul

Array = jax.Array

# ---------------------------------------------------------------------------
# op tracing (for the paper's Table I / Table VI op-count analyses)
# ---------------------------------------------------------------------------
_OP_TRACE: list | None = None


class OpTrace:
    """Context manager that records (op, dims) for every conv/linear/bn/add
    executed inside — run the model under ``jax.eval_shape`` to collect the
    exact per-layer op counts the paper tabulates."""

    def __enter__(self):
        global _OP_TRACE
        self._prev, _OP_TRACE = _OP_TRACE, []
        return self

    def __exit__(self, *exc):
        global _OP_TRACE
        self.ops, _OP_TRACE = _OP_TRACE, self._prev
        return False


def _trace(kind: str, **dims):
    if _OP_TRACE is not None:
        _OP_TRACE.append((kind, dims))


def ew_add(a: Array, b: Array) -> Array:
    """Element-wise residual add (traced: paper Table I counts these)."""
    _trace("ew_add", numel=int(jnp.size(a)))
    return a + b


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------
def kaiming(key, shape, fan_in, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype) * jnp.sqrt(2.0 / fan_in)


def xavier(key, shape, fan_in, fan_out, dtype=jnp.float32):
    lim = jnp.sqrt(6.0 / (fan_in + fan_out))
    return jax.random.uniform(key, shape, dtype, -lim, lim)


def trunc_normal(key, shape, std=0.02, dtype=jnp.float32):
    return jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype) * std


# ---------------------------------------------------------------------------
# linear / conv with optional MLS quantization
# ---------------------------------------------------------------------------
def init_linear(key, d_in, d_out, bias=False, dtype=jnp.float32, std=None):
    kw, kb = jax.random.split(key)
    w = (
        trunc_normal(kw, (d_in, d_out), std, dtype)
        if std is not None
        else xavier(kw, (d_in, d_out), d_in, d_out, dtype)
    )
    p = {"w": w}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p, x, qcfg: QuantConfig | None = None, key=None, wire=None):
    """x: (..., d_in) @ w (d_in, d_out); bias (if any) added in fp32.

    ``wire``: which weight dim is FSDP-sharded (pins the FSDP gather onto
    the quantized low-precision values — §Perf; None disables)."""
    _trace(
        "fc",
        d_in=p["w"].shape[0],
        d_out=p["w"].shape[1],
        rows=int(jnp.size(x) // x.shape[-1]),
        quantized=qcfg is not None and qcfg.enabled,
    )
    if qcfg is not None and qcfg.enabled:
        if qcfg.backend == "pallas":
            from repro.kernels import lowbit_matmul_qd

            # quantized-domain path: the FSDP wire pinning is a fake-quant
            # concern (the Pallas path already moves 1-byte codes).  The
            # kernels honor qcfg.grouping / block_m / block_n — unset
            # blocks resolve per-shape through the autotuner cache.
            y = lowbit_matmul_qd(x, p["w"].astype(jnp.float32), key, qcfg)
        else:
            if wire is not None and qcfg.wire_fsdp_dim != wire:
                import dataclasses as _dc

                qcfg = _dc.replace(qcfg, wire_fsdp_dim=wire)
            y = lowbit_matmul(x, p["w"].astype(jnp.float32), key, qcfg)
    else:
        dt = x.dtype
        y = jax.lax.dot_general(
            x, p["w"].astype(dt),
            (((x.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
    if "b" in p:
        y = y + p["b"].astype(jnp.float32)
    return y


def init_conv(key, c_in, c_out, ksize, dtype=jnp.float32):
    fan_in = c_in * ksize * ksize
    return {"w": kaiming(key, (c_out, c_in, ksize, ksize), fan_in, dtype)}


def conv2d(p, x, stride=1, padding="SAME", qcfg: QuantConfig | None = None, key=None):
    """NCHW conv; quantized per paper Alg. 1 when qcfg is given."""
    s = (stride, stride) if isinstance(stride, int) else stride
    co, ci, kh, kw = p["w"].shape
    _trace(
        "conv",
        c_in=ci, c_out=co, k=kh,
        h=x.shape[2] // s[0], w=x.shape[3] // s[1], n=x.shape[0],
        quantized=qcfg is not None and qcfg.enabled,
    )
    if qcfg is not None and qcfg.enabled:
        if qcfg.backend == "pallas":
            from repro.kernels import lowbit_conv_fused

            return lowbit_conv_fused(x, p["w"], key, s, padding, qcfg)
        return lowbit_conv(x, p["w"], key, s, padding, qcfg)
    return jax.lax.conv_general_dilated(
        x, p["w"].astype(x.dtype), s, padding,
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
        preferred_element_type=jnp.float32,
    )


# ---------------------------------------------------------------------------
# normalization
# ---------------------------------------------------------------------------
def init_batchnorm(c):
    return {"gamma": jnp.ones((c,)), "beta": jnp.zeros((c,))}


def batchnorm(p, x, eps=5e-5):
    """Training-mode BN over (N, H, W) of NCHW, fp32 (paper keeps BN full
    precision; eps matches paper Eq. 13)."""
    _trace("bn", numel=int(jnp.size(x)))
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=(0, 2, 3), keepdims=True)
    var = jnp.mean(jnp.square(x), axis=(0, 2, 3), keepdims=True) - jnp.square(mu)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return y * p["gamma"][None, :, None, None] + p["beta"][None, :, None, None]


def init_layernorm(d):
    return {"gamma": jnp.ones((d,)), "beta": jnp.zeros((d,))}


def layernorm(p, x, eps=1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["gamma"] + p["beta"]).astype(x.dtype)


def init_rmsnorm(d):
    return {"gamma": jnp.ones((d,))}


def rmsnorm(p, x, eps=1e-6):
    x32 = x.astype(jnp.float32)
    y = x32 * jax.lax.rsqrt(jnp.mean(jnp.square(x32), axis=-1, keepdims=True) + eps)
    return (y * p["gamma"]).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------
def rope_angles(positions: Array, head_dim: int, theta: float = 10000.0,
                rotary_dim: int | None = None):
    """Returns (sin, cos) of shape (..., rotary_dim/2)."""
    rd = rotary_dim or head_dim
    inv = 1.0 / (theta ** (jnp.arange(0, rd, 2, dtype=jnp.float32) / rd))
    ang = positions[..., None].astype(jnp.float32) * inv
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: Array, sin: Array, cos: Array, rotary_dim: int | None = None):
    """x: (B, S, H, D). Rotates the first ``rotary_dim`` dims (half-rotary
    style used by GLM when rotary_dim < D)."""
    d = x.shape[-1]
    rd = rotary_dim or d
    xr, xp = x[..., :rd], x[..., rd:]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    sin = sin[:, :, None, :]
    cos = cos[:, :, None, :]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([out, xp], axis=-1) if rd < d else out


# ---------------------------------------------------------------------------
# attention (GQA, causal, optional sliding window, optional KV cache)
# ---------------------------------------------------------------------------
def _gqa_attention_block(q, k, v, causal, q_offset, window, kv_len):
    b, sq, hkv, g, d = q.shape
    sk = k.shape[1]
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask = mask & (kpos <= qpos)
    if window is not None:
        mask = mask & (kpos > qpos - window)
    if kv_len is not None:
        mask = mask & (kpos < kv_len)
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))


def gqa_attention(
    q: Array,  # (B, Sq, Hq, D)
    k: Array,  # (B, Sk, Hkv, D)
    v: Array,  # (B, Sk, Hkv, D)
    causal: bool = True,
    q_offset: Array | int = 0,  # position of q[0] within the kv sequence
    window: int | None = None,  # sliding-window size (None = full)
    kv_len: Array | None = None,  # number of valid cache slots
    q_chunk: int | None = None,  # memory-efficient query chunking
):
    """Grouped-query attention.  With ``q_chunk`` the query axis is scanned
    in blocks (exact softmax per block over the full key range) so the score
    matrix never exceeds (B, H, q_chunk, Sk) — required for 32k+ prefill."""
    b, sq, hq, d = q.shape
    _, sk, hkv, _ = k.shape
    assert hq % hkv == 0
    g = hq // hkv
    qg = q.reshape(b, sq, hkv, g, d)
    if q_chunk is None or sq <= q_chunk or sq % q_chunk != 0:
        out = _gqa_attention_block(qg, k, v, causal, q_offset, window, kv_len)
        return out.reshape(b, sq, hq, d)

    nq = sq // q_chunk
    qb = qg.reshape(b, nq, q_chunk, hkv, g, d).transpose(1, 0, 2, 3, 4, 5)

    def body(_, inp):
        qi, i = inp
        off = q_offset + i * q_chunk
        return None, _gqa_attention_block(qi, k, v, causal, off, window, kv_len)

    _, out = jax.lax.scan(body, None, (qb, jnp.arange(nq)))
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, hq, d)
    return out
