"""Unified transformer components: GQA attention (full/half-rotary, optional
QKV bias, sliding window, KV cache) and gated MLP — every GEMM optionally
routed through the paper's MLS low-bit training path.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import QuantConfig
from repro.parallel import shard
from . import nn

Array = jax.Array


def _fold(key, tag):
    return None if key is None else jax.random.fold_in(key, tag)


def norm_init(cfg: ModelConfig, d=None):
    d = d or cfg.d_model
    return nn.init_rmsnorm(d) if cfg.norm == "rmsnorm" else nn.init_layernorm(d)


def norm_apply(cfg: ModelConfig, p, x):
    return nn.rmsnorm(p, x) if cfg.norm == "rmsnorm" else nn.layernorm(p, x)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------
def init_attention(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    d, hd = cfg.d_model, cfg.hd
    return {
        "wq": nn.init_linear(ks[0], d, cfg.n_heads * hd, cfg.qkv_bias, std=0.02),
        "wk": nn.init_linear(ks[1], d, cfg.n_kv_heads * hd, cfg.qkv_bias, std=0.02),
        "wv": nn.init_linear(ks[2], d, cfg.n_kv_heads * hd, cfg.qkv_bias, std=0.02),
        "wo": nn.init_linear(ks[3], cfg.n_heads * hd, d, False, std=0.02),
    }


def apply_attention(
    p,
    x: Array,  # (B, S, d)
    cfg: ModelConfig,
    qcfg: QuantConfig | None,
    key,
    *,
    causal: bool = True,
    positions: Array | None = None,  # (B, S) absolute positions of x
    cache: tuple[Array, Array] | None = None,  # (B, M, KV, hd) x2
    cache_pos: Array | int = 0,  # write offset into the cache
    kv_valid: Array | int | None = None,  # #valid cache slots (ring buffers)
    window: int | None = None,
    kv: Array | None = None,  # cross-attention source (B, Sk, d)
    cross_cache: tuple[Array, Array] | None = None,  # read-only K/V
):
    b, s, d = x.shape
    hd = cfg.hd
    q = nn.linear(p["wq"], x, qcfg, _fold(key, 0), wire=0).reshape(b, s, cfg.n_heads, hd)
    q = shard(q, "batch", "seq", "heads", None)
    q_chunk = 1024 if s > 4096 else None

    if cross_cache is not None:
        # cross-attention over precomputed encoder K/V: no rope, no update
        ck, cv = cross_cache
        out = nn.gqa_attention(q, ck, cv, causal=False, q_chunk=q_chunk)
        out = out.reshape(b, s, cfg.n_heads * hd).astype(x.dtype)
        return nn.linear(p["wo"], out, qcfg, _fold(key, 3), wire=1), None

    xkv = kv if kv is not None else x
    sk = xkv.shape[1]
    k = nn.linear(p["wk"], xkv, qcfg, _fold(key, 1), wire=0).reshape(b, sk, cfg.n_kv_heads, hd)
    v = nn.linear(p["wv"], xkv, qcfg, _fold(key, 2), wire=0).reshape(b, sk, cfg.n_kv_heads, hd)
    # "kv_seq" (not "seq"): under sequence parallelism K/V gather their
    # sequence dim (cheap for GQA) while Q stays sequence-sharded.
    k = shard(k, "batch", "kv_seq", "kv_heads", None)
    v = shard(v, "batch", "kv_seq", "kv_heads", None)

    if kv is None and cfg.rotary_pct > 0:  # no rope on cross-attention
        rd = int(hd * cfg.rotary_pct)
        if positions is None:  # absolute positions (decode: offset by cache)
            # NB: for ring-buffer caches the caller supplies true positions.
            positions = (jnp.arange(s) + cache_pos)[None, :] * jnp.ones(
                (b, 1), jnp.int32
            )
        sin, cos = nn.rope_angles(positions, hd, cfg.rope_theta, rd)
        q = nn.apply_rope(q, sin, cos, rd)
        k = nn.apply_rope(k, sin, cos, rd)

    if cache is not None:
        ck, cv = cache
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, cache_pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, cache_pos, 0, 0))
        if kv_valid is not None:
            # ring buffer: slot order is arbitrary; rope carries positions
            out = nn.gqa_attention(q, ck, cv, causal=False, kv_len=kv_valid,
                                   q_chunk=q_chunk)
        else:
            out = nn.gqa_attention(q, ck, cv, causal=causal,
                                   q_offset=cache_pos, window=window,
                                   kv_len=cache_pos + s, q_chunk=q_chunk)
        new_cache = (ck, cv)
    else:
        out = nn.gqa_attention(q, k, v, causal=causal and kv is None,
                               window=window, q_chunk=q_chunk)
        new_cache = None

    out = shard(out, "batch", "seq", "heads", None)
    out = out.reshape(b, s, cfg.n_heads * hd).astype(x.dtype)
    y = nn.linear(p["wo"], out, qcfg, _fold(key, 3), wire=1)
    return y, new_cache


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------
def init_mlp(key, cfg: ModelConfig, d_ff: int | None = None):
    ks = jax.random.split(key, 3)
    d, f = cfg.d_model, d_ff or cfg.d_ff
    p = {
        "w_up": nn.init_linear(ks[0], d, f, False, std=0.02),
        "w_down": nn.init_linear(ks[1], f, d, False, std=0.02),
    }
    if cfg.gated_mlp:
        p["w_gate"] = nn.init_linear(ks[2], d, f, False, std=0.02)
    return p


def apply_mlp(p, x, cfg: ModelConfig, qcfg, key):
    up = nn.linear(p["w_up"], x, qcfg, _fold(key, 10), wire=0)
    if cfg.gated_mlp:
        gate = nn.linear(p["w_gate"], x, qcfg, _fold(key, 11), wire=0)
        h = jax.nn.silu(gate) * up
    else:
        h = jax.nn.gelu(up)
    h = shard(h.astype(x.dtype), "batch", "seq", "mlp")
    return nn.linear(p["w_down"], h, qcfg, _fold(key, 12), wire=1)


# ---------------------------------------------------------------------------
# decoder block (dense)
# ---------------------------------------------------------------------------
def init_block(key, cfg: ModelConfig):
    ka, km = jax.random.split(key)
    return {
        "ln1": norm_init(cfg),
        "attn": init_attention(ka, cfg),
        "ln2": norm_init(cfg),
        "mlp": init_mlp(km, cfg),
    }


def apply_block(
    p, x, cfg: ModelConfig, qcfg, key, *,
    positions=None, cache=None, cache_pos=0, kv_valid=None, window=None,
    causal=True,
):
    h, new_cache = apply_attention(
        p["attn"], norm_apply(cfg, p["ln1"], x), cfg, qcfg, key,
        causal=causal, positions=positions, cache=cache, cache_pos=cache_pos,
        kv_valid=kv_valid, window=window,
    )
    x = shard(x + h.astype(x.dtype), "batch", "seq", "embed")
    h = apply_mlp(p["mlp"], norm_apply(cfg, p["ln2"], x), cfg, qcfg, key)
    x = shard(x + h.astype(x.dtype), "batch", "seq", "embed")
    return x, new_cache
