"""Post-optimization HLO text parser (shared by the roofline dry-run and the
quantization-coverage auditor).

XLA's ``cost_analysis()`` counts a ``while`` body ONCE, but scan-over-layers
puts ~all compute/collectives inside while bodies.  This parser:

1. splits the compiled module into computations,
2. finds every ``while``, reads its trip count from the loop-bound constant
   in the *condition* computation, and propagates multipliers through nested
   loops,
3. sums **dot FLOPs** (operand shapes resolved within the computation,
   bucketed by lhs dtype) and **collective wire bytes per device** (from
   output shapes + replica group sizes, bucketed by payload dtype), each
   scaled by its computation's multiplier.

Wire-byte conventions (ring algorithms, per participating device):
    all-gather        out_bytes * (g-1)/g
    all-reduce        2 * out_bytes * (g-1)/g
    reduce-scatter    out_bytes * (g-1)          (out = the local shard)
    all-to-all        out_bytes * (g-1)/g
    collective-permute  out_bytes
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

__all__ = [
    "analyze_hlo",
    "split_computations",
    "computation_multipliers",
    "Computation",
    "DTYPE_BYTES",
]

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}
_DTYPE_BYTES = DTYPE_BYTES  # back-compat alias

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_WHILE_RE = re.compile(
    r"while\(.*?\).*?(?:condition=%?([\w\.\-]+)).*?(?:body=%?([\w\.\-]+))"
    r"|while\(.*?\).*?(?:body=%?([\w\.\-]+)).*?(?:condition=%?([\w\.\-]+))"
)
_CALLEE_RE = re.compile(r"(?:to_apply|calls|body|condition)=%?([\w\.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(type_str: str) -> int:
    m = _SHAPE_RE.match(type_str)
    if not m:
        return 0
    dt, dims = m.groups()
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * DTYPE_BYTES.get(dt, 4)


def _shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.match(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Computation:
    name: str
    lines: list[str]
    defs: dict[str, str] = dataclasses.field(default_factory=dict)  # var -> type str


def split_computations(hlo: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    header = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^{]*)?\{\s*$")
    instr = re.compile(r"^\s*(?:ROOT\s+)?%?[\w\.\-]+\s*=")
    for line in hlo.splitlines():
        if cur is None:
            m = header.match(line.strip())
            if m and not instr.match(line):
                cur = Computation(m.group(1), [])
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        cur.lines.append(line)
        dm = _DEF_RE.match(line)
        if dm:
            var, rhs = dm.groups()
            sm = _SHAPE_RE.match(rhs.strip()) or _SHAPE_RE.match(
                rhs.strip().lstrip("(")
            )
            if sm:
                cur.defs[var] = rhs.strip().lstrip("(")
    return comps


_TRIP_RE = re.compile(r'known_trip_count":\{"n":"(\d+)"')


def _loop_trip_count(while_line: str, cond: Computation | None) -> int:
    """Prefer XLA's ``known_trip_count`` backend_config; fall back to the
    loop-bound constant in the condition computation."""
    m = _TRIP_RE.search(while_line)
    if m:
        return int(m.group(1))
    if cond is None:
        return 1
    consts = [int(c) for line in cond.lines for c in _CONST_RE.findall(line)]
    return max(consts) if consts else 1


def computation_multipliers(comps: dict[str, Computation],
                            entry: str) -> dict[str, float]:
    """multiplier[c] = how many times computation c runs per step."""
    mult: dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    # iterate to fixpoint (call graph is a DAG; a few passes suffice)
    for _ in range(12):
        changed = False
        for cname, comp in comps.items():
            m = mult.get(cname, 0.0)
            if m == 0.0:
                continue
            for line in comp.lines:
                if " while(" in line or "= while(" in line.replace("  ", " "):
                    wm = _WHILE_RE.search(line)
                    if not wm:
                        continue
                    cond = wm.group(1) or wm.group(4)
                    body = wm.group(2) or wm.group(3)
                    trips = _loop_trip_count(line, comps.get(cond))
                    for callee, factor in ((body, trips), (cond, trips + 1)):
                        if callee in comps:
                            new = m * factor
                            if new > mult.get(callee, 0.0):
                                mult[callee] = new
                                changed = True
                else:
                    for callee in _CALLEE_RE.findall(line):
                        if callee in comps and m > mult.get(callee, 0.0):
                            mult[callee] = m
                            changed = True
        if not changed:
            break
    return dict(mult)


def _find_entry(hlo: str, comps: dict[str, Computation]) -> str:
    if not comps:
        return ""
    m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", hlo, re.M)
    if m and m.group(1) in comps:
        return m.group(1)
    return max(comps, key=lambda c: len(comps[c].lines))


def analyze_hlo(hlo: str) -> dict[str, float]:
    """Returns {dot_flops, dot_flops_by_dtype, coll_bytes, per-collective
    byte breakdown, n_collectives} — all per device, while-trip-corrected."""
    comps = split_computations(hlo)
    entry = _find_entry(hlo, comps)
    mult = computation_multipliers(comps, entry)

    dot_flops = 0.0
    dot_by_dtype = defaultdict(float)
    coll = defaultdict(float)
    coll_count = defaultdict(int)

    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        for line in comp.lines:
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            rhs = dm.group(2).strip()
            # ---- dots -----------------------------------------------------
            if " dot(" in rhs or rhs.startswith("dot("):
                out_dims = _shape_dims(rhs)
                cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rhs)
                # lhs shape: newer XLA prints operand types inline
                # (``dot(f32[16,32]{1,0} %var, ...)``); otherwise resolve the
                # operand name against the computation's defs.
                inner = rhs.split("dot(", 1)[1]
                tm = re.match(r"\s*(\w+)\[([\d,]*)\]", inner)
                if tm:
                    lhs_dt = tm.group(1)
                    lhs_dims = [int(d) for d in tm.group(2).split(",") if d]
                else:
                    ops = re.match(r"\s*%?([\w\.\-]+)", inner)
                    lhs_def = (
                        comp.defs.get(ops.group(1), "") if ops else ""
                    )
                    lhs_dims = _shape_dims(lhs_def)
                    lm = _SHAPE_RE.match(lhs_def)
                    lhs_dt = lm.group(1) if lm else "?"
                k = 1
                if cdims:
                    for d in cdims.group(1).split(","):
                        if d and int(d) < len(lhs_dims):
                            k *= lhs_dims[int(d)]
                out = 1
                for d in out_dims:
                    out *= d
                dot_flops += m * 2.0 * out * k
                dot_by_dtype[lhs_dt] += m * 2.0 * out * k
                continue
            # ---- collectives ----------------------------------------------
            for cop in _COLLECTIVES:
                if re.search(rf"\b{cop}(?:-start)?\(", rhs):
                    if f"{cop}-done" in rhs:
                        break
                    out_bytes = _total_bytes(rhs)
                    dt = (_SHAPE_RE.match(rhs.split("(", 1)[0]) or
                          _SHAPE_RE.search(rhs.split("(", 1)[0]))
                    dt = dt.group(1) if dt else "?"
                    g = _group_size(rhs)
                    if cop == "all-gather":
                        b = out_bytes * (g - 1) / g
                    elif cop == "all-reduce":
                        b = 2.0 * out_bytes * (g - 1) / g
                    elif cop == "reduce-scatter":
                        b = out_bytes * (g - 1)
                    elif cop == "all-to-all":
                        b = out_bytes * (g - 1) / g
                    else:  # collective-permute
                        b = out_bytes
                    coll[cop] += m * b
                    coll[f"{cop}:{dt}"] += m * b
                    coll_count[cop] += 1
                    break

    return {
        "dot_flops": dot_flops,
        "dot_flops_by_dtype": {k: float(v) for k, v in dot_by_dtype.items()},
        "coll_bytes": float(sum(v for k, v in coll.items() if ":" not in k)),
        "coll_breakdown": {k: float(v) for k, v in coll.items()},
        "coll_counts": dict(coll_count),
        "entry": entry,
    }


def _total_bytes(rhs: str) -> int:
    """Output bytes of an instruction (tuples: sum of leaf shapes before the
    op name)."""
    head = rhs.split("(", 1)[0]
    return sum(
        _shape_bytes(f"{dt}[{dims}]")
        for dt, dims in _SHAPE_RE.findall(head)
    )


def _group_size(rhs: str) -> int:
    m = _GROUPS_IOTA_RE.search(rhs)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_RE.search(rhs)
    if m:
        return max(len([x for x in m.group(1).split(",") if x.strip() != ""]), 1)
    return 2
