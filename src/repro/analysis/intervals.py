"""Interval abstract interpretation over jaxprs (kernel overflow prover).

The quantized-domain Pallas GEMM relies on one numerical invariant (paper
Sec. V-B, ``kernels/mls_matmul.py`` module doc): every *integer-valued*
accumulation — decoded code fractions, their products, and the intra-group
MAC — must stay below ``2^24`` in magnitude so fp32 arithmetic on it is
bit-exact integer arithmetic.  ``analysis/lint.py`` proves this with a
closed-form bound for the one shipped tiling; this module proves it for
**arbitrary kernel code** by abstract interpretation of the traced kernel
jaxpr in a reduced product of two domains:

* **Intervals** — every array is abstracted to one :class:`Interval`, a
  ``[lo, hi]`` range valid for all its elements plus an ``integer`` flag
  (every concretization is integer-valued: the property fp32-exactness
  cares about).  Positions are ignored, so any elementwise/shuffle op is
  sound.
* **Seed images** — an array produced by an elementwise chain from a single
  small-range integer source (e.g. the uint8 code operand of the decode)
  additionally carries the exact *image* of that source's values through
  the chain, evaluated concretely with numpy.  This keeps the correlation
  between a code's exponent and mantissa fields that plain intervals lose
  (a ``where(is_denorm, ...)`` join would over-bound the decoded fraction
  by 2x), so the decoded-fraction bound — and hence the accumulator-width
  proof — is exact and agrees bit-for-bit with the closed form of
  :func:`repro.core.formats.accumulation_bits`.

Transfer functions cover the primitive vocabulary of the shipped kernels
(bit ops, shifts, select/where, dot_general, reductions, state
``get``/``swap``/``addupdate``, ``cond`` with concrete or unknown
predicate, ``pjit`` recursion); unknown primitives degrade soundly to
``Interval.top()``.  ``dot_general`` and integer add/accumulate ops record
:class:`Accumulation` events; the prover in
:mod:`repro.analysis.kernel_verify` checks each against the ``2^24``
budget using the same ``ceil(log2(hi + 1))`` bit convention as
``accumulation_bits`` so the two provers flag identical configs.

Everything here is pure Python/numpy over jaxpr metadata — nothing is
executed or compiled, so it is safe in CI on any host.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Any

import numpy as np
from jax import core as jcore

__all__ = [
    "AbsVal",
    "Accumulation",
    "Interval",
    "InterpResult",
    "abstract_eval_jaxpr",
    "integer_bits",
]

_INF = float("inf")
_MAX_SEED_VALUES = 4096  # largest integer source range tracked exactly


def integer_bits(hi: float) -> int:
    """Unsigned integer bits needed for magnitudes up to ``hi`` —
    ``ceil(log2(hi + 1))``, the ``product_bits + ceil(log2(k_block))``
    convention of :func:`repro.core.formats.accumulation_bits`, so both
    provers flag exactly the same configurations."""
    if hi == _INF:
        return 1 << 30
    return max(int(math.ceil(hi)), 0).bit_length()


@dataclasses.dataclass(frozen=True)
class Interval:
    """Value range of every element of an array, with integerness."""

    lo: float
    hi: float
    integer: bool = False

    def __post_init__(self):
        assert self.lo <= self.hi, (self.lo, self.hi)

    # ---- constructors ----------------------------------------------------
    @staticmethod
    def top() -> Interval:
        return Interval(-_INF, _INF, False)

    @staticmethod
    def const(v: float) -> Interval:
        v = float(v)
        return Interval(v, v, v.is_integer())

    @staticmethod
    def of_dtype(dtype) -> Interval:
        """Widest sound seed for an input of the given dtype."""
        dt = np.dtype(dtype)
        if dt.kind in "ui":
            info = np.iinfo(dt)
            return Interval(float(info.min), float(info.max), True)
        if dt.kind == "b":
            return Interval(0.0, 1.0, True)
        return Interval.top()

    # ---- lattice ---------------------------------------------------------
    def join(self, other: Interval) -> Interval:
        return Interval(
            min(self.lo, other.lo), max(self.hi, other.hi),
            self.integer and other.integer,
        )

    @property
    def max_abs(self) -> float:
        return max(abs(self.lo), abs(self.hi))

    @property
    def bounded(self) -> bool:
        return self.lo > -_INF and self.hi < _INF

    @property
    def concrete(self) -> float | None:
        """The single value when the interval is a point, else None."""
        return self.lo if self.lo == self.hi else None

    # ---- arithmetic ------------------------------------------------------
    def __add__(self, o: Interval) -> Interval:
        return Interval(self.lo + o.lo, self.hi + o.hi,
                        self.integer and o.integer)

    def __sub__(self, o: Interval) -> Interval:
        return Interval(self.lo - o.hi, self.hi - o.lo,
                        self.integer and o.integer)

    def __neg__(self) -> Interval:
        return Interval(-self.hi, -self.lo, self.integer)

    def __mul__(self, o: Interval) -> Interval:
        cands = [_mul(a, b) for a in (self.lo, self.hi)
                 for b in (o.lo, o.hi)]
        return Interval(min(cands), max(cands), self.integer and o.integer)

    def scale(self, k: float) -> Interval:
        """Multiply by a non-negative scalar (contraction-depth sums)."""
        assert k >= 0
        return Interval(_mul(self.lo, k), _mul(self.hi, k),
                        self.integer and float(k).is_integer())

    def abs(self) -> Interval:
        if self.lo >= 0:
            return self
        if self.hi <= 0:
            return -self
        return Interval(0.0, self.max_abs, self.integer)

    def truediv(self, o: Interval) -> Interval:
        if o.lo > 0 or o.hi < 0:
            cands = [a / b for a in (self.lo, self.hi)
                     for b in (o.lo, o.hi)]
            return Interval(min(cands), max(cands), False)
        return Interval.top()  # divisor range spans 0

    def min_(self, o: Interval) -> Interval:
        return Interval(min(self.lo, o.lo), min(self.hi, o.hi),
                        self.integer and o.integer)

    def max_(self, o: Interval) -> Interval:
        return Interval(max(self.lo, o.lo), max(self.hi, o.hi),
                        self.integer and o.integer)

    def floor(self) -> Interval:
        return Interval(math.floor(self.lo) if self.lo > -_INF else -_INF,
                        math.floor(self.hi) if self.hi < _INF else _INF,
                        True)

    def ceil(self) -> Interval:
        return Interval(math.ceil(self.lo) if self.lo > -_INF else -_INF,
                        math.ceil(self.hi) if self.hi < _INF else _INF,
                        True)

    def round(self) -> Interval:
        return Interval(round(self.lo) if self.lo > -_INF else -_INF,
                        round(self.hi) if self.hi < _INF else _INF,
                        True)

    def exp2(self) -> Interval:
        lo = 2.0 ** self.lo if self.lo > -_INF else 0.0
        hi = 2.0 ** self.hi if self.hi < _INF else _INF
        return Interval(lo, hi, False)

    def to_int(self) -> Interval:
        """convert_element_type to an integer dtype (truncation lies in the
        floor/ceil envelope of the source range)."""
        if not self.bounded:
            return Interval(-_INF, _INF, True)
        return Interval(float(math.floor(self.lo)),
                        float(math.ceil(self.hi)), True)

    # ---- bit ops ---------------------------------------------------------
    def bit_and(self, o: Interval) -> Interval:
        # x & m with m >= 0 lands in [0, m] regardless of x's sign (two's
        # complement); used by the decode field masks.
        for mask, _other in ((o, self), (self, o)):
            if mask.lo >= 0 and mask.bounded:
                return Interval(0.0, mask.hi, True)
        return Interval(-_INF, _INF, True)

    def bit_or(self, o: Interval) -> Interval:
        if self.lo >= 0 and o.lo >= 0 and self.bounded and o.bounded:
            bits = max(integer_bits(self.hi), integer_bits(o.hi))
            # OR only sets bits: result >= each operand, < 2^bits
            return Interval(max(self.lo, o.lo), float(2**bits - 1), True)
        return Interval(-_INF, _INF, True)

    def bit_xor(self, o: Interval) -> Interval:
        if self.lo >= 0 and o.lo >= 0 and self.bounded and o.bounded:
            # XOR can clear any bit (x ^ x = 0), so unlike OR the lower
            # bound is 0, never max(lo_a, lo_b).
            bits = max(integer_bits(self.hi), integer_bits(o.hi))
            return Interval(0.0, float(2**bits - 1), True)
        return Interval(-_INF, _INF, True)

    def shift_left(self, o: Interval) -> Interval:
        if o.lo >= 0 and o.bounded and self.bounded:
            f = 2.0 ** int(o.hi)
            lo = min(self.lo, self.lo * f)
            hi = max(self.hi, self.hi * f)
            return Interval(lo, hi, self.integer)
        return Interval(-_INF, _INF, self.integer)

    def shift_right(self, o: Interval) -> Interval:
        if o.lo >= 0 and o.bounded and self.bounded and self.lo >= 0:
            return Interval(math.floor(self.lo / 2.0 ** int(o.hi)),
                            self.hi, True)
        return Interval(-_INF, _INF, True)

    def to_json(self) -> dict:
        def num(v):
            return v if abs(v) != _INF else ("inf" if v > 0 else "-inf")

        return {"lo": num(self.lo), "hi": num(self.hi),
                "integer": self.integer}

    def __str__(self) -> str:
        tag = "int" if self.integer else "f32"
        return f"[{self.lo:g}, {self.hi:g}]{tag}"


def _mul(a: float, b: float) -> float:
    """IEEE-safe product for interval endpoints (0 * inf -> 0)."""
    if a == 0.0 or b == 0.0:
        return 0.0
    return a * b


_BOOL = Interval(0.0, 1.0, True)
_seed_counter = itertools.count()


@dataclasses.dataclass(frozen=True)
class AbsVal:
    """Abstract array value: interval hull + optional exact seed image.

    ``vals`` (when present) is the concrete image of one small-range
    integer source through the elementwise chain that produced this array;
    ``src`` identifies the source so two images are only combined when they
    describe the same seed.  The interval is always the hull of ``vals``
    when ``vals`` exists.
    """

    iv: Interval
    src: int | None = None
    vals: np.ndarray | None = None

    @staticmethod
    def of(iv: Interval) -> AbsVal:
        return AbsVal(iv)

    @staticmethod
    def const(v: float) -> AbsVal:
        return AbsVal(Interval.const(v))

    @staticmethod
    def seeded(iv: Interval) -> AbsVal:
        """Seed a new exact image when the interval is a small integer
        range (e.g. a uint8 code operand)."""
        if (iv.integer and iv.bounded
                and iv.hi - iv.lo + 1 <= _MAX_SEED_VALUES):
            vals = np.arange(int(iv.lo), int(iv.hi) + 1, dtype=np.float64)
            return AbsVal(iv, next(_seed_counter), vals)
        return AbsVal(iv)

    def join(self, o: AbsVal) -> AbsVal:
        if (self.src is not None and self.src == o.src
                and self.vals is not None and o.vals is not None):
            # per-seed-value join: either image may occur for that value
            lo = np.minimum(self.vals, o.vals)
            hi = np.maximum(self.vals, o.vals)
            if np.array_equal(lo, hi):
                return AbsVal(self.iv.join(o.iv), self.src, lo)
        return AbsVal(self.iv.join(o.iv))


def _hull(vals: np.ndarray) -> Interval:
    lo, hi = float(np.min(vals)), float(np.max(vals))
    integer = bool(np.all(vals == np.floor(vals)))
    return Interval(lo, hi, integer)


# numpy realizations of elementwise primitives for the seed-image domain
def _np_shift_left(a, b):
    # Scale in float64: multiplying by a power of two is exact until it
    # overflows to inf, where the isfinite bail-out reverts to intervals.
    # An int64 `<<` would instead wrap silently once integer_bits(a) + b
    # reaches 64, corrupting the "exact" image with finite garbage.
    with np.errstate(over="ignore", invalid="ignore"):
        res = a.astype(np.float64) * np.exp2(b.astype(np.float64))
        return np.where(a == 0, 0.0, res)


def _np_shift_right(a, b):
    return (a.astype(np.int64) >> b.astype(np.int64)).astype(np.float64)


_NP_UNARY = {
    "neg": np.negative,
    "abs": np.abs,
    "sign": np.sign,
    "floor": np.floor,
    "ceil": np.ceil,
    "round": np.round,
    "nearbyint": np.round,
    "exp2": np.exp2,
    "not": lambda a: 1.0 - a,
}
_NP_BINARY = {
    "add": np.add,
    "sub": np.subtract,
    "mul": np.multiply,
    "max": np.maximum,
    "min": np.minimum,
    "and": lambda a, b: (a.astype(np.int64) & b.astype(np.int64)).astype(
        np.float64),
    "or": lambda a, b: (a.astype(np.int64) | b.astype(np.int64)).astype(
        np.float64),
    "xor": lambda a, b: (a.astype(np.int64) ^ b.astype(np.int64)).astype(
        np.float64),
    "shift_left": _np_shift_left,
    "shift_right_arithmetic": _np_shift_right,
    "shift_right_logical": _np_shift_right,
    "eq": lambda a, b: (a == b).astype(np.float64),
    "ne": lambda a, b: (a != b).astype(np.float64),
    "lt": lambda a, b: (a < b).astype(np.float64),
    "le": lambda a, b: (a <= b).astype(np.float64),
    "gt": lambda a, b: (a > b).astype(np.float64),
    "ge": lambda a, b: (a >= b).astype(np.float64),
}
# Value- and order-preserving layout ops: flat element order is unchanged,
# so the image passes through with its seed identity intact and stays
# pointwise-aligned with other images of the same seed.
_EXACT_LAYOUT_PRIMS = frozenset({
    "reshape", "squeeze", "expand_dims", "copy", "stop_gradient",
    "reduce_precision",
})
# Value-preserving but element-rearranging/selecting/duplicating ops: the
# output's values are still a subset of the input's, so the image remains a
# sound per-element over-approximation — but positional correspondence with
# the seed is broken (x[0:4] and x[4:8] carry the same image yet pair
# *different* seed elements), so the image survives only under a FRESH seed
# identity; binary ops between two rearrangements of one source then fall
# back to sound interval rules instead of pointwise alignment.
_REARRANGE_PRIMS = frozenset({
    "broadcast_in_dim", "transpose", "slice", "dynamic_slice", "rev",
    "gather", "reduce_max", "reduce_min",
})
_LAYOUT_PRIMS = _EXACT_LAYOUT_PRIMS | _REARRANGE_PRIMS


@dataclasses.dataclass
class Accumulation:
    """One accumulation event the overflow prover must budget.

    ``kind``: ``"dot"`` (an MXU contraction summing ``depth`` products per
    output element) or ``"acc"`` (a running add / reduce).  ``bound`` is
    the statically proven max |result|.  Only *integer* accumulations carry
    the fp32-exactness obligation; float ones are recorded with
    ``integer=False`` for visibility but not gated.
    """

    kind: str
    bound: float
    integer: bool
    depth: int
    operand_bound: float

    @property
    def bits(self) -> int:
        return integer_bits(self.bound)

    def to_json(self) -> dict:
        def num(v):
            return v if v != _INF else "inf"

        return {"kind": self.kind, "bound": num(self.bound),
                "bits": min(self.bits, 9999), "integer": self.integer,
                "depth": self.depth, "operand_bound": num(self.operand_bound)}


@dataclasses.dataclass
class InterpResult:
    """Outcome of one abstract pass over a jaxpr."""

    accumulations: list[Accumulation]
    warnings: list[str]

    def max_integer_accumulation(self) -> Accumulation | None:
        ints = [a for a in self.accumulations if a.integer]
        return max(ints, key=lambda a: a.bound) if ints else None


class _Env:
    """Var -> AbsVal environment with literal handling."""

    def __init__(self):
        self._m: dict[Any, AbsVal] = {}

    def read(self, atom) -> AbsVal:
        if isinstance(atom, jcore.Literal):
            try:
                return AbsVal.const(float(atom.val))
            except (TypeError, ValueError):
                return AbsVal.of(Interval.top())
        return self._m.get(atom, AbsVal.of(Interval.top()))

    def write(self, var, v: AbsVal) -> None:
        self._m[var] = v


def _dot_depth(eqn) -> int:
    (lhs_c, _), _batch = eqn.params["dimension_numbers"]
    shape = tuple(eqn.invars[0].aval.shape)
    return math.prod(int(shape[d]) for d in lhs_c) or 1


def _reduce_depth(eqn) -> int:
    axes = eqn.params.get("axes", ())
    shape = tuple(eqn.invars[0].aval.shape)
    return math.prod(int(shape[a]) for a in axes) or 1


def _aligned_images(ins: list[AbsVal]) -> tuple[int, list[np.ndarray]] | None:
    """Images of all operands over one shared seed, lifting constants."""
    src, length = None, None
    for v in ins:
        if v.vals is not None:
            if src is None:
                src, length = v.src, len(v.vals)
            elif v.src != src:
                return None
    if src is None:
        return None
    out = []
    for v in ins:
        if v.vals is not None:
            out.append(v.vals)
        elif v.iv.concrete is not None:
            out.append(np.full(length, v.iv.concrete, dtype=np.float64))
        else:
            return None
    return src, out


class _Interp:
    """One abstract execution of a (kernel) jaxpr.

    ``program_ids`` maps grid axis -> Interval (a point when the caller is
    enumerating grid steps).  Refs are ordinary vars whose AbsVal is the
    *current content bound*; get/swap/addupdate read and update it, and ref
    vars passed into cond/pjit sub-jaxprs alias their operand so writes
    propagate back out.
    """

    def __init__(self, program_ids: dict[int, Interval]):
        self.program_ids = program_ids
        self.result = InterpResult([], [])
        self._warned: set[str] = set()

    def warn(self, msg: str) -> None:
        if msg not in self._warned:
            self._warned.add(msg)
            self.result.warnings.append(msg)

    def _acc(self, kind: str, bound: float, integer: bool, depth: int,
             operand_bound: float) -> None:
        self.result.accumulations.append(
            Accumulation(kind, bound, integer, depth, operand_bound))

    # ------------------------------------------------------------------
    def run(self, jaxpr: jcore.Jaxpr, env: _Env) -> list[AbsVal]:
        for eqn in jaxpr.eqns:
            self.eqn(eqn, env)
        return [env.read(v) for v in jaxpr.outvars]

    # ------------------------------------------------------------------
    def eqn(self, eqn, env: _Env) -> None:
        prim = eqn.primitive.name
        # structural / stateful primitives first
        if prim == "cond":
            self._cond(eqn, env)
            return
        if prim in ("pjit", "closed_call", "core_call", "custom_jvp_call",
                    "custom_vjp_call", "custom_vjp_call_jaxpr", "remat",
                    "checkpoint"):
            self._call(eqn, env)
            return

        ins = [env.read(a) for a in eqn.invars]

        def out(v: AbsVal, idx: int = 0) -> None:
            env.write(eqn.outvars[idx], v)

        if prim == "program_id":
            out(AbsVal.of(self.program_ids.get(int(eqn.params["axis"]),
                                               Interval.top())))
            return
        if prim == "get":
            # reading a small-int ref (the packed codes) seeds a fresh
            # exact image for the decode chain downstream
            content = ins[0]
            if content.vals is None:
                seeded = AbsVal.seeded(content.iv)
                out(dataclasses.replace(seeded, iv=content.iv))
            else:
                out(content)
            return
        if prim == "swap":
            # swap(ref, val) -> old; ref := val.  Strong update only when
            # the write covers the whole ref; partial writes join.
            out(ins[0])
            ref_shape = tuple(getattr(eqn.invars[0].aval, "shape", ()))
            val_shape = tuple(getattr(eqn.invars[1].aval, "shape", ()))
            if val_shape == ref_shape:
                env.write(eqn.invars[0], ins[1])
            else:
                env.write(eqn.invars[0], ins[0].join(ins[1]))
            return
        if prim == "addupdate":
            new_iv = ins[0].iv + ins[1].iv
            if new_iv.integer:
                self._acc("acc", new_iv.max_abs, True, 1,
                          max(ins[0].iv.max_abs, ins[1].iv.max_abs))
            env.write(eqn.invars[0], AbsVal.of(new_iv))
            return
        if prim == "dot_general":
            depth = _dot_depth(eqn)
            per = ins[0].iv * ins[1].iv
            res = Interval(_mul(min(per.lo, 0.0), depth),
                           _mul(max(per.hi, 0.0), depth), per.integer)
            self._acc("dot", res.max_abs, per.integer, depth, per.max_abs)
            out(AbsVal.of(res))
            return
        if prim in ("reduce_sum", "cumsum"):
            if prim == "cumsum":
                ax = eqn.params.get("axis")
                depth = (int(eqn.invars[0].aval.shape[ax])
                         if ax is not None else _reduce_depth(eqn))
            else:
                depth = _reduce_depth(eqn)
            src = ins[0].iv
            res = Interval(_mul(min(src.lo, 0.0), depth),
                           _mul(max(src.hi, 0.0), depth), src.integer)
            self._acc("acc", res.max_abs, src.integer, depth, src.max_abs)
            out(AbsVal.of(res))
            return
        if prim in ("reduce_and", "reduce_or"):
            out(AbsVal.of(_BOOL))
            return
        if prim == "iota":
            size = math.prod(int(s) for s in eqn.outvars[0].aval.shape)
            out(AbsVal.of(Interval(0.0, float(max(size - 1, 0)), True)))
            return
        if prim == "select_n":
            out(self._select_n(ins))
            return
        if prim == "convert_element_type":
            dt = np.dtype(eqn.params["new_dtype"])
            src = ins[0]
            if src.vals is not None:
                vals = (np.trunc(src.vals) if dt.kind in "ui"
                        else src.vals.astype(np.float64))
                out(AbsVal(_hull(vals), src.src, vals))
            elif dt.kind in "ui":
                riv = src.iv.to_int()
                rng = Interval.of_dtype(dt)
                if not (riv.lo >= rng.lo and riv.hi <= rng.hi):
                    riv = rng  # int conversion wraps into the dtype range
                out(AbsVal.of(riv))
            else:
                out(AbsVal.of(Interval(src.iv.lo, src.iv.hi, src.iv.integer)))
            return
        if prim == "bitcast_convert_type":
            out(self._bitcast(eqn, ins[0]))
            return
        if prim == "clamp":
            lo, x, hi = ins[0].iv, ins[1].iv, ins[2].iv
            out(AbsVal.of(Interval(
                min(max(x.lo, lo.lo), hi.hi), min(max(x.hi, lo.lo), hi.hi),
                x.integer and lo.integer and hi.integer)))
            return
        if prim in _LAYOUT_PRIMS:
            v = ins[0]
            if v.vals is not None and prim in _REARRANGE_PRIMS:
                v = dataclasses.replace(v, src=next(_seed_counter))
            out(v)
            return
        if prim in ("concatenate", "pad", "dynamic_update_slice"):
            joined = ins[0]
            for o in ins[1:]:
                joined = joined.join(o)
            out(AbsVal.of(joined.iv))
            return

        # elementwise: try the exact seed-image domain first
        if prim in _NP_UNARY or prim in _NP_BINARY:
            img = _aligned_images(ins)
            if img is not None:
                src, arrs = img
                fn = _NP_UNARY.get(prim) or _NP_BINARY[prim]
                with np.errstate(all="ignore"):
                    vals = fn(*arrs)
                if np.all(np.isfinite(vals)):
                    out(AbsVal(_hull(vals), src, vals))
                    return
        out(self._interval_rule(prim, eqn, ins))

    # ------------------------------------------------------------------
    def _interval_rule(self, prim: str, eqn, ins: list[AbsVal]) -> AbsVal:
        iv = [v.iv for v in ins]
        if prim in ("add", "add_any"):
            res = iv[0] + iv[1]
            if res.integer:
                self._acc("acc", res.max_abs, True, 1,
                          max(iv[0].max_abs, iv[1].max_abs))
            return AbsVal.of(res)
        table = {
            "sub": lambda: iv[0] - iv[1],
            "mul": lambda: iv[0] * iv[1],
            "neg": lambda: -iv[0],
            "abs": lambda: iv[0].abs(),
            "sign": lambda: Interval(-1.0, 1.0, True),
            "div": lambda: iv[0].truediv(iv[1]),
            "max": lambda: iv[0].max_(iv[1]),
            "min": lambda: iv[0].min_(iv[1]),
            "floor": lambda: iv[0].floor(),
            "ceil": lambda: iv[0].ceil(),
            "round": lambda: iv[0].round(),
            "nearbyint": lambda: iv[0].round(),
            "exp2": lambda: iv[0].exp2(),
            "and": lambda: iv[0].bit_and(iv[1]),
            "or": lambda: iv[0].bit_or(iv[1]),
            "xor": lambda: iv[0].bit_xor(iv[1]),
            "not": lambda: _BOOL,
            "shift_left": lambda: iv[0].shift_left(iv[1]),
            "shift_right_arithmetic": lambda: iv[0].shift_right(iv[1]),
            "shift_right_logical": lambda: iv[0].shift_right(iv[1]),
            "integer_pow": lambda: abs_pow(iv[0], eqn.params.get("y", 2)),
            "square": lambda: iv[0] * iv[0],
            "rsqrt": lambda: Interval(0.0, _INF, False),
            "sqrt": lambda: Interval(0.0, _INF, False),
        }
        if prim == "rem":
            a, b = iv[0], iv[1]
            ca, cb = a.concrete, b.concrete
            if ca is not None and cb is not None and cb != 0:
                return AbsVal.const(float(math.fmod(ca, cb)))
            if a.integer and b.integer and a.lo >= 0 and b.lo >= 1 \
                    and b.bounded:
                # truncated remainder of nonneg by positive: [0, b.hi - 1],
                # and never larger than the dividend itself
                return AbsVal.of(Interval(
                    0.0, min(a.hi, b.hi - 1.0) if a.bounded else b.hi - 1.0,
                    True))
            return AbsVal.of(Interval.top())
        if prim in ("eq", "ne", "lt", "le", "gt", "ge"):
            c0, c1 = iv[0].concrete, iv[1].concrete
            if c0 is not None and c1 is not None:
                val = {"eq": c0 == c1, "ne": c0 != c1, "lt": c0 < c1,
                       "le": c0 <= c1, "gt": c0 > c1, "ge": c0 >= c1}[prim]
                return AbsVal.const(float(val))
            return AbsVal.of(_BOOL)
        if prim in table:
            return AbsVal.of(table[prim]())
        self.warn(f"no interval rule for primitive '{prim}'; widening to top")
        return AbsVal.of(Interval.top())

    # ------------------------------------------------------------------
    def _select_n(self, ins: list[AbsVal]) -> AbsVal:
        pred, cases = ins[0], ins[1:]
        img = _aligned_images(ins)
        if img is not None:
            src, arrs = img
            p = np.clip(np.trunc(arrs[0]), 0, len(cases) - 1).astype(np.int64)
            vals = np.choose(p, arrs[1:])
            return AbsVal(_hull(vals), src, vals)
        c = pred.iv.concrete
        if c is not None and 0 <= int(c) < len(cases):
            return cases[int(c)]
        v = cases[0]
        for o in cases[1:]:
            v = v.join(o)
        return v

    # ------------------------------------------------------------------
    def _bitcast(self, eqn, src: AbsVal) -> AbsVal:
        dt = np.dtype(eqn.params["new_dtype"])
        src_dt = np.dtype(eqn.invars[0].aval.dtype)
        iv = src.iv
        if dt == src_dt:
            return src  # identity cast (e.g. int32 -> int32)
        if (dt.kind in "ui" and src_dt.kind in "ui"
                and dt.itemsize == src_dt.itemsize and iv.integer
                and iv.bounded and iv.lo >= 0
                and iv.hi < 2.0 ** (8 * dt.itemsize - 1)):
            return src  # same bits, both interpretations non-negative
        if dt == np.float32 and iv.integer and iv.bounded and iv.lo >= 0 \
                and iv.hi < float(0x7F800000):
            # non-negative fp32 bit patterns order like their float values,
            # so the pattern interval maps monotonically to a float interval
            # (this is what keeps Exponent/Fraction's frac in [1, 2))
            lo = float(np.array(int(iv.lo), np.int32).view(np.float32))
            hi = float(np.array(int(iv.hi), np.int32).view(np.float32))
            return AbsVal.of(Interval(lo, hi, False))
        return AbsVal.of(Interval.of_dtype(dt))

    # ------------------------------------------------------------------
    def _cond(self, eqn, env: _Env) -> None:
        branches = eqn.params["branches"]
        operands = eqn.invars[1:]
        pred = env.read(eqn.invars[0]).iv.concrete

        def run_branch(br) -> tuple[list[AbsVal], dict]:
            sub = br.jaxpr if isinstance(br, jcore.ClosedJaxpr) else br
            benv = _Env()
            for cv in sub.constvars:
                benv.write(cv, AbsVal.of(Interval.top()))
            for v, a in zip(sub.invars, operands):
                benv.write(v, env.read(a))
            for beqn in sub.eqns:
                self.eqn(beqn, benv)
            outs = [benv.read(v) for v in sub.outvars]
            writes = {}
            for v, a in zip(sub.invars, operands):
                if not isinstance(a, jcore.Literal):
                    writes[a] = benv.read(v)
            return outs, writes

        if pred is not None and 0 <= int(pred) < len(branches):
            outs, writes = run_branch(branches[int(pred)])
            for a, val in writes.items():
                env.write(a, val)
        else:
            results = [run_branch(br) for br in branches]
            outs = []
            for i in range(len(eqn.outvars)):
                v = results[0][0][i]
                for o, _ in results[1:]:
                    v = v.join(o[i])
                outs.append(v)
            touched = {a for _, w in results for a in w}
            for a in touched:
                v = env.read(a)
                for _, w in results:
                    v = v.join(w.get(a, v))
                env.write(a, v)
        for v, val in zip(eqn.outvars, outs):
            env.write(v, val)

    # ------------------------------------------------------------------
    def _call(self, eqn, env: _Env) -> None:
        sub = None
        for v in eqn.params.values():
            if isinstance(v, jcore.ClosedJaxpr):
                sub = v.jaxpr
                break
            if isinstance(v, jcore.Jaxpr):
                sub = v
                break
        if sub is None:
            for v in eqn.outvars:
                env.write(v, AbsVal.of(Interval.top()))
            return
        senv = _Env()
        for cv in sub.constvars:
            senv.write(cv, AbsVal.of(Interval.top()))
        for v, a in zip(sub.invars, eqn.invars):
            senv.write(v, env.read(a))
        for seqn in sub.eqns:
            self.eqn(seqn, senv)
        # propagate ref-content updates made inside the call back out
        for v, a in zip(sub.invars, eqn.invars):
            if not isinstance(a, jcore.Literal):
                env.write(a, senv.read(v))
        for ov, sv in zip(eqn.outvars, sub.outvars):
            env.write(ov, senv.read(sv))


def abs_pow(iv: Interval, y: int) -> Interval:
    if y < 0:
        return Interval.top()
    res = Interval.const(1.0)
    for _ in range(int(y)):
        res = res * iv
    return res


def abstract_eval_jaxpr(
    jaxpr: jcore.Jaxpr,
    in_intervals: list[Interval],
    *,
    program_ids: dict[int, Interval] | None = None,
    steps: list[dict[int, int]] | None = None,
) -> tuple[list[Interval], InterpResult]:
    """Interval-interpret ``jaxpr`` (a Pallas kernel body or any jaxpr).

    ``in_intervals`` seeds the invars (for refs, the seed is the content
    bound of the backing buffer).  ``steps``, when given, replays the body
    once per entry with those concrete ``program_id`` values while ref
    state persists across steps — the sequential-grid semantics of the
    revisiting-accumulator pattern.  Without ``steps`` a single pass runs
    with symbolic ``program_ids``.

    Returns the final invar intervals (ref end-state bounds) and the
    :class:`InterpResult` with every accumulation event observed.
    """
    env = _Env()
    for v, iv in zip(jaxpr.invars, in_intervals):
        # small-int inputs (packed codes) get an exact seed image up front,
        # exactly as a ref `get` would seed one inside a kernel body — the
        # image over the full range is a sound superset of any element set
        env.write(v, AbsVal.seeded(iv))
    pid_default = {i: Interval.top() for i in range(8)}
    if program_ids:
        pid_default.update(program_ids)
    interp = _Interp(dict(pid_default))
    if steps is None:
        interp.run(jaxpr, env)
    else:
        for step in steps:
            pids = dict(pid_default)
            pids.update({ax: Interval.const(v) for ax, v in step.items()})
            interp.program_ids = pids
            interp.run(jaxpr, env)
    final = [env.read(v).iv for v in jaxpr.invars]
    return final, interp.result
