"""Wire-byte budget audit of the MLS-compressed cross-pod gradient ring.

Lowers ``parallel.compress.crosspod_allreduce_mean`` under ``shard_map`` on
an ``n_pods``-wide mesh, compiles it (AOT, nothing executed), and feeds the
post-optimization HLO to :mod:`repro.analysis.hlo_parser` to count the
actual collective-permute bytes per device.  The compressed ring must move

    per hop:  n codes (1 B) + n/block group scales (4 B) + 1 tensor scale

instead of the fp32 ring's ``4n`` bytes per hop — a ~3.88x reduction for
block=128.  The audit asserts the *compiled* graph achieves this: a
regression (XLA upcasting the codes, an accidental fp32 exchange, scales
blown up to full shape) shows up as a collapsed compression ratio.

Requires >= n_pods devices; the CLI forces host devices via XLA_FLAGS
(``--xla_force_host_platform_device_count``) before first JAX backend use.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.analysis.hlo_parser import analyze_hlo
from repro.core import FMT_IMAGENET, EMFormat

__all__ = ["audit_wire_ring"]


def _shard_map():
    sm = getattr(jax, "shard_map", None)
    if sm is None:  # jax < 0.6 keeps it in experimental
        from jax.experimental.shard_map import shard_map as sm
    return sm


def audit_wire_ring(
    n_elems: int = 1 << 16,
    n_pods: int = 2,
    fmt: EMFormat = FMT_IMAGENET,
    block: int = 128,
) -> dict:
    """AOT-compile the compressed ring and report wire bytes per device."""
    if len(jax.devices()) < n_pods:
        raise RuntimeError(
            f"wire audit needs {n_pods} devices, have {len(jax.devices())}; "
            f"set XLA_FLAGS=--xla_force_host_platform_device_count={n_pods} "
            f"before JAX initializes its backend"
        )
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import make_mesh
    from repro.parallel.compress import crosspod_allreduce_mean

    mesh = make_mesh((n_pods,), ("pod",))

    @partial(_shard_map(), mesh=mesh, in_specs=P("pod", None),
             out_specs=P("pod", None))
    def ring(x):  # x: (1, n_elems) per pod
        return crosspod_allreduce_mean(x[0], "pod", fmt=fmt)[None]

    g = jax.ShapeDtypeStruct((n_pods, n_elems), jnp.float32)
    compiled = jax.jit(ring).lower(g).compile()
    hlo = compiled.as_text()
    res = analyze_hlo(hlo)

    actual = res["coll_breakdown"].get("collective-permute", 0.0)
    breakdown = {
        k.split(":", 1)[1]: v
        for k, v in res["coll_breakdown"].items()
        if k.startswith("collective-permute:")
    }
    # fp32 ring moving the same gradient: (p-1) hops of 4n bytes
    fp32_ring = 4.0 * n_elems * (n_pods - 1)
    # ideal compressed payload (codes + group scales + tensor scale)
    ideal = (n_elems + 4.0 * n_elems / block + 4.0) * (n_pods - 1)
    ratio = fp32_ring / actual if actual else 0.0
    return {
        "n_elems": n_elems,
        "n_pods": n_pods,
        "fmt": str(fmt),
        "block": block,
        "wire_bytes_per_device": actual,
        "wire_bytes_by_dtype": breakdown,
        "fp32_ring_bytes_per_device": fp32_ring,
        "ideal_compressed_bytes_per_device": ideal,
        "compression_ratio": ratio,
        "n_collective_permutes": res["coll_counts"].get(
            "collective-permute", 0
        ),
    }
