"""Static verifier for the shipped Pallas kernels.

Two proofs per ``pallas_call``, both computed from traced jaxpr metadata
without executing or compiling anything:

**Grid / index-map coverage** — the grid is enumerated and every BlockSpec
index map is evaluated at every grid point (``jax.core.eval_jaxpr`` on
concrete indices), proving for each output that

* every output block is written at least once (no *gaps*),
* any block revisited across grid steps is revisited only along grid
  dimensions its index map does not depend on — the legal
  revisiting-accumulator pattern; two writes from points that differ in a
  *dependent* dimension are conflicting (*overlap*),
* all reads/writes land in bounds and array dims divide their block shape.

**Accumulator exactness** — the kernel body jaxpr is abstractly interpreted
in the interval ⊗ seed-image domain of :mod:`repro.analysis.intervals`,
replaying the body once per (used) grid step so VMEM scratch state
persists exactly as the sequential Pallas grid executes it.  Every
*integer* accumulation event (decoded-code dot products, running adds)
must stay below ``2^24`` so fp32 arithmetic on it is bit-exact — the
invariant behind the paper's energy argument (Sec. V-B).  This generalizes
``analysis/lint.py``'s closed-form ``accumulation_bits`` bound to
arbitrary kernel code, and agrees with it bit-for-bit on the shipped GEMM
(:func:`prove_matmul_accumulation_bits`).

Entry points: :func:`verify_entry` (a ``KERNEL_REGISTRY`` entry),
:func:`verify_candidate` (the autotuner's legality oracle for a
``(shape, qcfg, blocks)`` tiling candidate), and
:func:`run_kernel_audit` (the ``--kernels`` section of
``python -m repro.analysis.audit``, including the ``--sabotage`` negative
controls).
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from collections.abc import Iterator
from typing import TYPE_CHECKING

if TYPE_CHECKING:  # registry imports kernels; keep runtime import lazy
    from repro.kernels.registry import KernelEntry

import jax
import jax.numpy as jnp
from jax import core as jcore

from repro.core.formats import EMFormat, FMT_IMAGENET, GS_FMT_DEFAULT
from repro.core.lowbit import QuantConfig
from repro.analysis.intervals import Interval, abstract_eval_jaxpr

__all__ = [
    "ACC_BUDGET_BITS",
    "CallReport",
    "KernelReport",
    "Violation",
    "find_pallas_eqns",
    "prove_matmul_accumulation_bits",
    "prove_window_grid",
    "run_kernel_audit",
    "verify_candidate",
    "verify_closed_jaxpr",
    "verify_entry",
    "verify_implicit_conv_candidate",
    "verify_quantize_candidate",
]

ACC_BUDGET_BITS = 24      # fp32 integer-exactness budget (paper Sec. V-B)
_MAX_GRID_POINTS = 1 << 18  # full index-map enumeration cap
_MAX_STEP_REPLAYS = 2048    # abstract body replays over used grid axes
_MAX_UNUSED_REPLAYS = 8     # unused-axis subgrid replays before fixpoint gate

SABOTAGE_MODES = ("overlap_write", "deep_k", "drop_halo")


# ---------------------------------------------------------------------------
# jaxpr plumbing
# ---------------------------------------------------------------------------
def _iter_sub_jaxprs(val) -> Iterator[jcore.Jaxpr]:
    if isinstance(val, jcore.Jaxpr):
        yield val
    elif isinstance(val, jcore.ClosedJaxpr):
        yield val.jaxpr
    elif isinstance(val, (tuple, list)):
        for v in val:
            yield from _iter_sub_jaxprs(v)


def find_pallas_eqns(jaxpr: jcore.Jaxpr) -> list:
    """All ``pallas_call`` eqns in ``jaxpr``, recursing into sub-jaxprs."""
    out = []
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            out.append(eqn)
        for v in eqn.params.values():
            for sub in _iter_sub_jaxprs(v):
                out.extend(find_pallas_eqns(sub))
    return out


def _used_program_axes(jaxpr: jcore.Jaxpr) -> set[int]:
    axes: set[int] = set()
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "program_id":
            axes.add(int(eqn.params["axis"]))
        for v in eqn.params.values():
            for sub in _iter_sub_jaxprs(v):
                axes |= _used_program_axes(sub)
    return axes


# ---------------------------------------------------------------------------
# report dataclasses
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Violation:
    """One statically proven defect in a kernel's grid or arithmetic."""

    kind: str    # gap | overlap | oob | divisibility | overflow | unproven
    where: str   # block-mapping origin ("outputs[0]", "args[2]") or "body"
    detail: str

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class CallReport:
    """Verification result for one ``pallas_call``."""

    kernel: str
    grid: tuple[int, ...]
    violations: list[Violation]
    coverage: dict
    accumulations: list[dict]
    max_integer_bits: int
    out_bounds: dict
    warnings: list[str]
    exhaustive: bool

    @property
    def ok(self) -> bool:
        return not self.violations

    def to_json(self) -> dict:
        return {
            "kernel": self.kernel,
            "grid": list(self.grid),
            "ok": self.ok,
            "exhaustive": self.exhaustive,
            "violations": [v.to_json() for v in self.violations],
            "coverage": self.coverage,
            "max_integer_accumulation_bits": self.max_integer_bits,
            "accumulations": self.accumulations,
            "out_bounds": self.out_bounds,
            "warnings": self.warnings,
        }


@dataclasses.dataclass
class KernelReport:
    """Aggregated verification of one kernel entry point (all its calls)."""

    name: str
    calls: list[CallReport]

    @property
    def ok(self) -> bool:
        return bool(self.calls) and all(c.ok for c in self.calls)

    @property
    def max_integer_bits(self) -> int:
        return max((c.max_integer_bits for c in self.calls), default=0)

    @property
    def violations(self) -> list[Violation]:
        return [v for c in self.calls for v in c.violations]

    def to_json(self) -> dict:
        return {
            "name": self.name,
            "ok": self.ok,
            "num_pallas_calls": len(self.calls),
            "max_integer_accumulation_bits": self.max_integer_bits,
            "calls": [c.to_json() for c in self.calls],
        }


# ---------------------------------------------------------------------------
# coverage proofs
# ---------------------------------------------------------------------------
def _eval_index_map(bm, point: tuple[int, ...]) -> tuple[int, ...]:
    im = bm.index_map_jaxpr
    res = jcore.eval_jaxpr(im.jaxpr, im.consts, *point)
    return tuple(int(r) for r in res)


def _dependent_dims(table: dict, ndim: int) -> set[int]:
    """Grid dims whose value the index map's output actually varies with."""
    deps: set[int] = set()
    for d in range(ndim):
        seen: dict = {}
        for pt, idx in table.items():
            key = pt[:d] + pt[d + 1:]
            if key in seen:
                if seen[key] != idx:
                    deps.add(d)
                    break
            else:
                seen[key] = idx
    return deps


def _check_operand(
    name: str, bm, grid: tuple[int, ...], points: list[tuple[int, ...]],
    is_output: bool,
) -> tuple[list[Violation], dict | None]:
    viols: list[Violation] = []
    shape = tuple(int(s) for s in bm.array_shape_dtype.shape)
    bs = tuple(int(b) for b in bm.block_shape)
    for i, (s, b) in enumerate(zip(shape, bs)):
        if b < 1 or s % b:
            viols.append(Violation(
                "divisibility", name,
                f"dim {i}: array extent {s} not divisible by block {b}",
            ))
    table = {pt: _eval_index_map(bm, pt) for pt in points}
    nblocks = tuple(-(-s // b) for s, b in zip(shape, bs))
    oob = [
        (pt, idx) for pt, idx in table.items()
        if any(ix < 0 or ix >= nb for ix, nb in zip(idx, nblocks))
    ]
    if oob:
        pt, idx = oob[0]
        word = "write" if is_output else "read"
        viols.append(Violation(
            "oob", name,
            f"{word} out of bounds: grid point {pt} -> block {idx} outside "
            f"{nblocks} ({len(oob)} of {len(table)} grid points)",
        ))
    if not is_output:
        return viols, None

    deps = sorted(_dependent_dims(table, len(grid)))
    groups: dict[tuple, list[tuple]] = {}
    for pt, idx in table.items():
        groups.setdefault(idx, []).append(pt)
    for idx, pts in groups.items():
        by_proj: dict[tuple, tuple] = {}
        for p in pts:
            by_proj.setdefault(tuple(p[d] for d in deps), p)
        if len(by_proj) > 1:
            pa, pb = list(by_proj.values())[:2]
            viols.append(Violation(
                "overlap", name,
                f"output block {idx} written from grid points {pa} and {pb}, "
                f"which differ in grid dims {deps} that the index map "
                f"depends on — conflicting writes, not a legal revisit",
            ))
            break
    required = set(itertools.product(*[range(n) for n in nblocks]))
    missing = sorted(required - set(groups))
    if missing:
        viols.append(Violation(
            "gap", name,
            f"{len(missing)} of {len(required)} output blocks never "
            f"written, e.g. block {missing[0]}",
        ))
    cov = {
        "output_blocks": len(required),
        "blocks_written": len(set(groups) & required),
        "revisit_depth": max(len(p) for p in groups.values()),
        "index_map_grid_dims": deps,
    }
    return viols, cov


# ---------------------------------------------------------------------------
# overflow proof
# ---------------------------------------------------------------------------
def _prove_body(eqn, grid: tuple[int, ...]):
    gm = eqn.params["grid_mapping"]
    body = eqn.params["jaxpr"]
    if isinstance(body, jcore.ClosedJaxpr):
        body = body.jaxpr
    warnings: list[str] = []
    exhaustive = True

    seeds = [
        Interval.of_dtype(bm.block_aval.inner_aval.dtype)
        for bm in gm.block_mappings
    ]
    seeds += [Interval.top()] * int(gm.num_scratch_operands)
    if len(seeds) != len(body.invars):
        warnings.append(
            f"body has {len(body.invars)} invars but {len(seeds)} block "
            f"mappings + scratch; widening the rest to top"
        )
        seeds = (seeds + [Interval.top()] * len(body.invars))[
            : len(body.invars)]

    used = sorted(_used_program_axes(body) & set(range(len(grid))))
    sizes = [grid[a] for a in used]
    steps = None
    if used and math.prod(sizes) <= _MAX_STEP_REPLAYS:
        steps = [
            dict(zip(used, combo))
            for combo in itertools.product(*[range(n) for n in sizes])
        ]
    elif used:
        warnings.append(
            f"grid axes {used} span {math.prod(sizes)} steps > "
            f"{_MAX_STEP_REPLAYS}; falling back to one symbolic pass"
        )
        exhaustive = False

    finals, res = abstract_eval_jaxpr(body, seeds, steps=steps)
    accs = list(res.accumulations)
    warnings += res.warnings
    violations: list[Violation] = []

    # The sequential grid replays the used-axes subgrid once per setting of
    # the unused axes, with scratch state carried across replays.  Re-run
    # the abstraction seeded with the previous pass's end state until it
    # reaches a fixpoint (a well-formed kernel re-initializes its
    # accumulators every replay) or the concrete replay count is exhausted.
    # State still widening once the cap cuts the iteration short means the
    # recorded bounds under-cover the remaining concrete replays, so it
    # gates as unproven rather than merely warning.
    unused_repeat = math.prod(
        g for a, g in enumerate(grid) if a not in used
    ) if grid else 1
    if steps is not None and unused_repeat > 1:
        replays = min(unused_repeat, _MAX_UNUSED_REPLAYS)
        widening = False
        for _ in range(replays - 1):
            finals2, res2 = abstract_eval_jaxpr(body, finals, steps=steps)
            accs += res2.accumulations
            widening = any(
                (f2.lo < f1.lo or f2.hi > f1.hi)
                for f1, f2 in zip(finals, finals2)
            )
            finals = finals2
            if not widening:
                break
        if widening and unused_repeat > replays:
            violations.append(Violation(
                "unproven", "body",
                f"ref state keeps widening after {replays} of "
                f"{unused_repeat} grid replays (accumulator not "
                f"re-initialized per output tile?): accumulation bounds "
                f"for the remaining replays are not covered",
            ))
    return finals, accs, warnings, exhaustive, violations


def verify_pallas_eqn(eqn, name: str) -> CallReport:
    """Run both proofs on one traced ``pallas_call`` eqn."""
    gm = eqn.params["grid_mapping"]
    grid = tuple(int(g) for g in gm.grid)
    violations: list[Violation] = []
    coverage: dict = {}
    warnings: list[str] = []
    exhaustive = True

    npoints = math.prod(grid) if grid else 1
    if int(gm.num_index_operands):
        warnings.append(
            f"{gm.num_index_operands} scalar-prefetch operands not modeled")
    if npoints > _MAX_GRID_POINTS:
        warnings.append(
            f"grid {grid} has {npoints} points > {_MAX_GRID_POINTS}; "
            f"coverage not proven")
        exhaustive = False
        points: list[tuple[int, ...]] = []
    else:
        points = list(itertools.product(*[range(g) for g in grid]))

    n_in, n_out = int(gm.num_inputs), int(gm.num_outputs)
    if points:
        for k, bm in enumerate(gm.block_mappings):
            is_output = k >= n_in
            where = str(getattr(bm, "origin", None) or (
                f"outputs[{k - n_in}]" if is_output else f"args[{k}]"))
            viols, cov = _check_operand(where, bm, grid, points, is_output)
            violations += viols
            if cov is not None:
                coverage[where] = cov

    finals, accs, body_warnings, body_exhaustive, body_viols = _prove_body(
        eqn, grid)
    warnings += body_warnings
    violations += body_viols
    exhaustive = exhaustive and body_exhaustive
    int_accs = [a for a in accs if a.integer]
    max_bits = max((a.bits for a in int_accs), default=0)
    for a in int_accs:
        if a.bits >= ACC_BUDGET_BITS:
            violations.append(Violation(
                "overflow", "body",
                f"integer {a.kind} accumulation spans {min(a.bits, 9999)} "
                f"bits (|bound| {a.bound:g}, depth {a.depth}, operand bound "
                f"{a.operand_bound:g}) >= {ACC_BUDGET_BITS}: fp32 "
                f"accumulation is no longer bit-exact",
            ))
            break
    out_bounds = {}
    for k in range(n_in, n_in + n_out):
        bm = gm.block_mappings[k]
        where = str(getattr(bm, "origin", None) or f"outputs[{k - n_in}]")
        if k < len(finals):
            out_bounds[where] = finals[k].to_json()

    seen = set()
    acc_json = []
    for a in accs:
        key = (a.kind, a.bound, a.depth, a.integer)
        if key not in seen:
            seen.add(key)
            acc_json.append(a.to_json())
    return CallReport(
        kernel=name, grid=grid, violations=violations, coverage=coverage,
        accumulations=acc_json, max_integer_bits=max_bits,
        out_bounds=out_bounds, warnings=sorted(set(warnings)),
        exhaustive=exhaustive,
    )


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------
def verify_closed_jaxpr(cj: jcore.ClosedJaxpr, name: str) -> KernelReport:
    eqns = find_pallas_eqns(cj.jaxpr)
    calls = [
        verify_pallas_eqn(eqn, f"{name}#{i}") for i, eqn in enumerate(eqns)
    ]
    if not calls:
        calls = [CallReport(
            kernel=name, grid=(), coverage={}, accumulations=[],
            max_integer_bits=0, out_bounds={}, warnings=[], exhaustive=False,
            violations=[Violation(
                "unproven", "body", "no pallas_call found in trace")],
        )]
    return KernelReport(name=name, calls=calls)


def verify_entry(entry: KernelEntry) -> KernelReport:
    """Verify one ``repro.kernels.KERNEL_REGISTRY`` entry."""
    return verify_closed_jaxpr(entry.trace(), entry.name)


def _unpack_qcfg(qcfg) -> tuple[EMFormat, int, EMFormat]:
    if isinstance(qcfg, QuantConfig):
        return qcfg.fmt, qcfg.k_block, qcfg.gs_fmt
    fmt, k_block = qcfg
    return fmt, int(k_block), GS_FMT_DEFAULT


def verify_candidate(
    shape: tuple[int, int, int], qcfg, blocks: tuple[int, int] | None = None,
    grouping: str | None = None,
) -> KernelReport:
    """Autotuner legality oracle: statically verify one tiling candidate.

    ``shape`` is the GEMM ``(M, K, N)``; ``qcfg`` a ``QuantConfig`` or a
    bare ``(fmt, k_block)`` pair (for sweeps over configs that
    ``QuantConfig`` itself would refuse to construct); ``blocks`` the
    ``(block_m, block_n)`` output tiling; ``grouping`` the group-scale
    layout (``None`` takes the QuantConfig's grouping, or ``"nc"``).  The
    full fused pipeline (quantize x, quantize w, quantized-domain GEMM) is
    traced at those shapes and every ``pallas_call`` is proven — nothing is
    compiled, so illegal tilings are pruned before costing a Mosaic
    compile.
    """
    M, K, N = shape
    fmt, k_block, gs_fmt = _unpack_qcfg(qcfg)
    if grouping is None:
        grouping = qcfg.grouping if isinstance(qcfg, QuantConfig) else "nc"
    block_m, block_n = blocks or (128, 128)
    from repro.kernels.ops import lowbit_matmul_fused

    def fn(x, w):
        return lowbit_matmul_fused(
            x, w, None, fmt=fmt, gs_fmt=gs_fmt, k_block=k_block,
            block_m=block_m, block_n=block_n, grouping=grouping,
            interpret=True,
        )

    cj = jax.make_jaxpr(fn)(
        jax.ShapeDtypeStruct((M, K), jnp.float32),
        jax.ShapeDtypeStruct((K, N), jnp.float32),
    )
    return verify_closed_jaxpr(
        cj,
        f"candidate_{M}x{K}x{N}_{fmt}_kb{k_block}_b{block_m}x{block_n}"
        f"_{grouping}",
    )


def verify_quantize_candidate(
    shape: tuple[int, int], fmt: EMFormat, k_block: int, block_m: int,
    gs_fmt: EMFormat = GS_FMT_DEFAULT, grouping: str = "nc",
) -> KernelReport:
    """Legality oracle for a quantizer tiling candidate: trace
    ``mls_quantize_pallas`` on an ``(M, K)`` operand at one ``block_m`` /
    ``grouping`` and statically prove every ``pallas_call`` (grid coverage
    + accumulator budget), without compiling."""
    M, K = shape
    from repro.kernels.mls_quantize import mls_quantize_pallas

    def fn(x):
        return mls_quantize_pallas(
            x, fmt, k_block, gs_fmt, None, block_m=block_m,
            interpret=True, grouping=grouping,
        )

    cj = jax.make_jaxpr(fn)(jax.ShapeDtypeStruct((M, K), jnp.float32))
    return verify_closed_jaxpr(
        cj, f"qcandidate_{M}x{K}_{fmt}_kb{k_block}_bm{block_m}_{grouping}")


def prove_window_grid(
    geom, bh: int, cb: int, block_n: int, *,
    band_h_override: int | None = None,
) -> tuple[list[Violation], dict]:
    """Coverage proof for the implicit-GEMM conv's halo'd window grid.

    The implicit kernel's activation BlockSpec fetches whole images — the
    actual patch addressing is the in-kernel halo-band load plus static
    strided tap slices, which the generic index-map enumeration cannot see.
    This replays that address arithmetic over the full grid and proves:

    * every halo band ``[row0, row0 + band_h)`` stays inside the padded
      input and contains every tap row its ``bh`` output rows need,
    * every ``(image, output_row)`` pair is produced by exactly one M-tile
      and every input channel by exactly one K-tile (no gaps, no overlaps),
    * tap column slices stay inside the padded width.

    ``band_h_override`` exists for the ``drop_halo`` negative control —
    shrinking the band must surface an ``oob`` violation here.
    """
    viols: list[Violation] = []
    cov: dict = {}
    oh, ow, kh, kw = geom.oh, geom.ow, geom.kh, geom.kw
    sh, sw, hp, wp = geom.sh, geom.sw, geom.hp, geom.wp
    for cond, msg in (
        (bh >= 1 and oh % bh == 0, f"bh={bh} must divide OH={oh}"),
        (cb >= 1 and geom.c % cb == 0, f"cb={cb} must divide C={geom.c}"),
        (block_n >= 1, f"block_n={block_n} must be positive"),
    ):
        if not cond:
            viols.append(Violation("divisibility", "window_grid", msg))
    if viols:
        return viols, cov
    band_h = sh * (bh - 1) + kh if band_h_override is None \
        else band_h_override
    oh_tiles, n_k = oh // bh, geom.c // cb
    m_tiles = geom.m0 // (bh * ow)
    rows_covered: dict[tuple[int, int], int] = {}
    for i in range(m_tiles):
        img, rt = divmod(i, oh_tiles)
        row0 = rt * bh * sh
        if img >= geom.n:
            viols.append(Violation(
                "oob", "window_grid",
                f"M-tile {i} addresses image {img} >= N={geom.n}"))
            break
        if row0 < 0 or row0 + band_h > hp:
            viols.append(Violation(
                "oob", "window_grid",
                f"M-tile {i}: halo band rows [{row0}, {row0 + band_h}) "
                f"outside padded input height {hp}"))
            break
        bad = next(
            ((r, kh_) for r in range(bh) for kh_ in range(kh)
             if kh_ + sh * r >= band_h), None)
        if bad is not None:
            r, kh_ = bad
            viols.append(Violation(
                "oob", "window_grid",
                f"M-tile {i}: output row {rt * bh + r} tap {kh_} needs "
                f"band row {kh_ + sh * r} >= band_h={band_h} — halo band "
                f"too short"))
            break
        for r in range(bh):
            key = (img, rt * bh + r)
            rows_covered[key] = rows_covered.get(key, 0) + 1
    if kw + sw * (ow - 1) > wp:
        viols.append(Violation(
            "oob", "window_grid",
            f"tap column slice spans {kw + sw * (ow - 1)} > padded width "
            f"{wp}"))
    if not any(v.kind == "oob" for v in viols):
        want = {(n_, r_) for n_ in range(geom.n) for r_ in range(oh)}
        missing = sorted(want - set(rows_covered))
        dup = sorted(k for k, v in rows_covered.items() if v > 1)
        if missing:
            viols.append(Violation(
                "gap", "window_grid",
                f"{len(missing)} of {len(want)} (image, output_row) pairs "
                f"never produced, e.g. {missing[0]}"))
        if dup:
            viols.append(Violation(
                "overlap", "window_grid",
                f"(image, output_row) {dup[0]} produced by multiple "
                f"M-tiles"))
        chans = [c_ for k in range(n_k) for c_ in range(k * cb, k * cb + cb)]
        if sorted(chans) != list(range(geom.c)) or any(
                c_ >= geom.c for c_ in chans):
            viols.append(Violation(
                "gap", "window_grid",
                f"K-tiles cover channels {sorted(set(chans))[:4]}... "
                f"instead of 0..{geom.c - 1} exactly once"))
    cov = {
        "output_blocks": geom.n * oh,
        "blocks_written": len(rows_covered),
        "band_h": band_h,
        "m_tiles": m_tiles,
        "k_tiles": n_k,
    }
    return viols, cov


def verify_implicit_conv_candidate(
    geom, fmt: EMFormat, k_block: int, bh: int, block_n: int,
    grouping: str = "nc", gs_fmt: EMFormat = GS_FMT_DEFAULT,
) -> KernelReport:
    """Legality oracle for an implicit-GEMM conv tiling candidate.

    Combines the generic pallas proofs (trace
    :func:`repro.kernels.implicit_conv.implicit_conv_forward` and prove
    every ``pallas_call``: BlockSpec coverage + the 2^24 accumulator
    budget over the fused quantize+GEMM body) with the window-grid proof
    of :func:`prove_window_grid`, which covers the in-kernel halo
    addressing the BlockSpec enumeration cannot see.
    """
    from repro.kernels.implicit_conv import implicit_compatible, \
        implicit_conv_forward

    name = (f"iconv_{'x'.join(str(d) for d in geom.as_dims())}"
            f"_{fmt}_kb{k_block}_bh{bh}_bn{block_n}_{grouping}")
    ok, reason = implicit_compatible(geom, k_block)
    window_viols: list[Violation] = []
    cov: dict = {}
    if not ok:
        window_viols.append(Violation("divisibility", "window_grid", reason))
    else:
        window_viols, cov = prove_window_grid(
            geom, bh, k_block // geom.kk, block_n)
    calls: list[CallReport] = [CallReport(
        kernel=f"{name}#window", grid=(), violations=window_viols,
        coverage={"window_grid": cov} if cov else {}, accumulations=[],
        max_integer_bits=0, out_bounds={}, warnings=[], exhaustive=True,
    )]
    if not window_viols:
        stride = (geom.sh, geom.sw)
        padding = [(geom.ph_lo, geom.ph_hi), (geom.pw_lo, geom.pw_hi)]

        def fn(x, w):
            return implicit_conv_forward(
                x, w, None, None, stride, padding, fmt=fmt, gs_fmt=gs_fmt,
                k_block=k_block, bh=bh, block_n=block_n, grouping=grouping,
                interpret=True,
            )

        try:
            cj = jax.make_jaxpr(fn)(
                jax.ShapeDtypeStruct(
                    (geom.n, geom.c, geom.h, geom.w), jnp.float32),
                jax.ShapeDtypeStruct(
                    (geom.o, geom.c, geom.kh, geom.kw), jnp.float32),
            )
        except ValueError as e:
            calls.append(CallReport(
                kernel=f"{name}#trace", grid=(), coverage={},
                accumulations=[], max_integer_bits=0, out_bounds={},
                warnings=[], exhaustive=True,
                violations=[Violation(
                    "divisibility", "trace",
                    f"kernel rejected the tiling: {e}")],
            ))
        else:
            calls += verify_closed_jaxpr(cj, name).calls
    return KernelReport(name=name, calls=calls)


def prove_matmul_accumulation_bits(fmt: EMFormat, k_block: int) -> int:
    """Interval-prover bound on the GEMM's integer accumulator width for
    one ``(fmt, k_block)`` — must equal
    :func:`repro.core.formats.accumulation_bits` (the lint's closed form)
    for every legal pair; the agreement is asserted in the test suite."""
    from repro.kernels.mls_matmul import mls_matmul_pallas

    M = N = 8
    K = 2 * k_block

    def fn(xc, xsg, xst, wc, wsg, wst):
        return mls_matmul_pallas(
            xc, xsg, xst, wc, wsg, wst, fmt, k_block=k_block,
            block_m=M, block_n=N, interpret=True,
        )

    cj = jax.make_jaxpr(fn)(
        jax.ShapeDtypeStruct((M, K), jnp.uint8),
        jax.ShapeDtypeStruct((M, K // k_block), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.float32),
        jax.ShapeDtypeStruct((K, N), jnp.uint8),
        jax.ShapeDtypeStruct((K // k_block, N), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.float32),
    )
    report = verify_closed_jaxpr(cj, f"prove_{fmt}_kb{k_block}")
    return report.max_integer_bits


# ---------------------------------------------------------------------------
# sabotage negative controls (CI must prove these fail)
# ---------------------------------------------------------------------------
def _sabotage_overlap_jaxpr() -> jcore.ClosedJaxpr:
    """Matmul-shaped kernel whose output index map folds two j-steps onto
    one block: ``(i, j - j % 2)`` writes block columns {0, 2} twice each and
    never writes {1, 3} — an overlap *and* a gap the verifier must name."""
    from jax.experimental import pallas as pl
    from jax.experimental.pallas import tpu as pltpu

    bm = bn = bk = 8
    n_k = 2

    def kernel(x_ref, w_ref, o_ref, acc_ref):
        k = pl.program_id(2)

        @pl.when(k == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        acc_ref[...] += jnp.dot(
            x_ref[...], w_ref[...], preferred_element_type=jnp.float32)

        @pl.when(k == n_k - 1)
        def _done():
            o_ref[...] = acc_ref[...]

    def fn(x, w):
        return pl.pallas_call(
            kernel,
            grid=(1, 4, n_k),
            in_specs=[
                pl.BlockSpec((bm, bk), lambda i, j, k: (0, k)),
                pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j - j % 2)),
            out_shape=jax.ShapeDtypeStruct((bm, 4 * bn), jnp.float32),
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
            interpret=True,
        )(x, w)

    return jax.make_jaxpr(fn)(
        jax.ShapeDtypeStruct((bm, n_k * bk), jnp.float32),
        jax.ShapeDtypeStruct((n_k * bk, 4 * bn), jnp.float32),
    )


def _sabotage_deep_k_jaxpr() -> jcore.ClosedJaxpr:
    """The shipped GEMM kernel at a contraction tile the closed form
    rejects: <2,4> x k_block=2048 accumulates 25 integer bits >= 24.
    ``QuantConfig`` refuses to construct this, but the raw kernel accepts
    it — exactly the hole the interval prover closes."""
    from repro.kernels.mls_matmul import mls_matmul_pallas

    fmt, k_block, M, N = FMT_IMAGENET, 2048, 8, 8
    K = k_block

    def fn(xc, xsg, xst, wc, wsg, wst):
        return mls_matmul_pallas(
            xc, xsg, xst, wc, wsg, wst, fmt, k_block=k_block,
            block_m=M, block_n=N, interpret=True,
        )

    return jax.make_jaxpr(fn)(
        jax.ShapeDtypeStruct((M, K), jnp.uint8),
        jax.ShapeDtypeStruct((M, 1), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.float32),
        jax.ShapeDtypeStruct((K, N), jnp.uint8),
        jax.ShapeDtypeStruct((1, N), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.float32),
    )


def _sabotage_drop_halo_report() -> KernelReport:
    """The implicit conv's window grid with the last halo row dropped:
    ``band_h - 1`` leaves the deepest tap of every M-tile's last output row
    unreadable — the window proof must name the ``oob``."""
    from repro.kernels.implicit_conv import conv_geometry

    geom = conv_geometry((2, 4, 8, 8), (8, 4, 3, 3), (1, 1), "SAME")
    bh, cb, bn = 2, 2, 8
    band_h = geom.sh * (bh - 1) + geom.kh
    viols, cov = prove_window_grid(
        geom, bh, cb, bn, band_h_override=band_h - 1)
    name = "sabotage:drop_halo"
    return KernelReport(name=name, calls=[CallReport(
        kernel=f"{name}#window", grid=(), violations=viols,
        coverage={"window_grid": cov} if cov else {}, accumulations=[],
        max_integer_bits=0, out_bounds={}, warnings=[], exhaustive=True,
    )])


# builders return either a ClosedJaxpr to verify or a finished KernelReport
_SABOTAGE_BUILDERS = {
    "overlap_write": _sabotage_overlap_jaxpr,
    "deep_k": _sabotage_deep_k_jaxpr,
    "drop_halo": _sabotage_drop_halo_report,
}


def run_kernel_audit(sabotage: str | None = None) -> dict:
    """Verify every ``KERNEL_REGISTRY`` entry (+ an optional planted
    negative control) and return the ``--kernels`` report section."""
    from repro.kernels import KERNEL_REGISTRY

    reports = {
        name: verify_entry(entry) for name, entry in KERNEL_REGISTRY.items()
    }
    if sabotage is not None:
        built = _SABOTAGE_BUILDERS[sabotage]()
        name = f"sabotage:{sabotage}"
        if isinstance(built, KernelReport):
            reports[name] = built
        else:
            reports[name] = verify_closed_jaxpr(built, name)
    return {
        "budget_bits": ACC_BUDGET_BITS,
        "ok": all(r.ok for r in reports.values()),
        "kernels": {name: r.to_json() for name, r in reports.items()},
    }
