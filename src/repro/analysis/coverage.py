"""Static quantized-domain coverage analysis of traced JAX programs.

The paper's energy argument (Sec. VII) requires *every* hot-path MAC to run
on MLS low-bit operands — a single silently-unquantized ``dot_general`` (a
layer that forgot its ``QuantConfig``, a backend that fell back to XLA fp32)
voids it.  This module walks a jaxpr — recursing through ``pjit``,
``custom_vjp``/``custom_jvp``, ``scan``, ``while``, ``cond``, ``remat``,
``shard_map`` and ``pallas_call`` — and classifies every FLOP-bearing
primitive (``dot_general``, ``conv_general_dilated``) into:

* ``quantized`` — a contraction executed inside a Pallas kernel on values
  decoded from packed integer MLS codes (both operands reach the dot through
  an int8/uint8 taint chain: the quantized-domain GEMM of
  ``mls_matmul_pallas``).  Pallas grid dimensions multiply the per-program
  MAC count, scan lengths multiply their body.
* ``data_movement`` — a conv whose filter is *constant-derived* (built from
  literals/iota with no dependence on any traced input).  This is the
  im2col patch extraction / col2im scatter of ``kernels.lowbit_conv``: a
  one-hot identity filter, i.e. a gather on real hardware, not MACs.  These
  are reported separately, never silently dropped.
* ``full_precision`` — everything else: XLA dots/convs on float operands
  (fake-quant simulation, attention score GEMMs, unquantized first/last
  layers, a planted fp32 op on the hot path).

MAC counting is static (shape arithmetic on avals); nothing is executed, so
full-scale graphs can be audited on any host via ``jax.make_jaxpr`` over
``ShapeDtypeStruct`` inputs.

``quantized_fraction = quantized / (quantized + full_precision)`` is the
number the CI gate compares against the checked-in baseline.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import core as jcore

__all__ = ["Site", "CoverageReport", "coverage_of_jaxpr", "trace_coverage"]

_INT_CODE_DTYPES = (jnp.uint8, jnp.int8)


@dataclasses.dataclass
class Site:
    """One FLOP-bearing primitive instance (multiplier-weighted)."""

    path: str  # scope chain, e.g. "pjit:train_step/scan/pallas:_kernel"
    kind: str  # "dot" | "conv"
    klass: str  # "quantized" | "full_precision" | "data_movement"
    macs: int  # multiply-accumulates, weighted by loop/grid multipliers
    out_shape: tuple

    def to_json(self) -> dict:
        return {
            "path": self.path, "kind": self.kind, "class": self.klass,
            "macs": self.macs, "out_shape": list(self.out_shape),
        }


@dataclasses.dataclass
class CoverageReport:
    sites: list[Site]
    warnings: list[str]

    def _total(self, klass: str) -> int:
        return sum(s.macs for s in self.sites if s.klass == klass)

    @property
    def quantized_macs(self) -> int:
        return self._total("quantized")

    @property
    def full_precision_macs(self) -> int:
        return self._total("full_precision")

    @property
    def data_movement_macs(self) -> int:
        return self._total("data_movement")

    @property
    def quantized_fraction(self) -> float:
        denom = self.quantized_macs + self.full_precision_macs
        return self.quantized_macs / denom if denom else 0.0

    def full_precision_sites(self) -> list[Site]:
        return sorted((s for s in self.sites if s.klass == "full_precision"),
                      key=lambda s: -s.macs)

    def to_json(self, top_sites: int = 24) -> dict:
        ranked = sorted(self.sites, key=lambda s: -s.macs)
        return {
            "quantized_macs": self.quantized_macs,
            "full_precision_macs": self.full_precision_macs,
            "data_movement_macs": self.data_movement_macs,
            "quantized_fraction": round(self.quantized_fraction, 6),
            "n_sites": len(self.sites),
            "sites": [s.to_json() for s in ranked[:top_sites]],
            "full_precision_sites": [
                s.to_json() for s in self.full_precision_sites()[:top_sites]
            ],
            "warnings": self.warnings,
        }


# ---------------------------------------------------------------------------
# jaxpr walking
# ---------------------------------------------------------------------------
def _aval_shape(atom) -> tuple:
    aval = getattr(atom, "aval", None)
    return tuple(getattr(aval, "shape", ()))


def _aval_is_int_code(atom) -> bool:
    aval = getattr(atom, "aval", None)
    dt = getattr(aval, "dtype", None)
    return dt is not None and any(dt == d for d in _INT_CODE_DTYPES)


def _prod(xs) -> int:
    return math.prod(int(x) for x in xs)


def _dot_macs(eqn) -> int:
    (lhs_c, _rhs_c), _batch = eqn.params["dimension_numbers"]
    lhs_shape = _aval_shape(eqn.invars[0])
    k = _prod(lhs_shape[d] for d in lhs_c)
    return _prod(_aval_shape(eqn.outvars[0])) * k


def _conv_macs(eqn) -> int:
    dn = eqn.params["dimension_numbers"]
    rhs_shape = _aval_shape(eqn.invars[1])
    rhs_spec = dn.rhs_spec  # (out_chan, in_chan, *spatial) dim indices
    k = rhs_shape[rhs_spec[1]] * _prod(rhs_shape[d] for d in rhs_spec[2:])
    return _prod(_aval_shape(eqn.outvars[0])) * k


def _sub_jaxprs(params: dict) -> list[tuple[str, Any]]:
    """All (param_name, Jaxpr|ClosedJaxpr) pairs of an eqn's params."""
    out = []
    for k, v in params.items():
        vs = v if isinstance(v, (list, tuple)) else [v]
        for vv in vs:
            if isinstance(vv, jcore.ClosedJaxpr):
                out.append((k, vv.jaxpr))
            elif isinstance(vv, jcore.Jaxpr):
                out.append((k, vv))
    return out


def _scope_name(eqn) -> str | None:
    """Human-readable scope for an eqn that has sub-jaxprs."""
    prim = eqn.primitive.name
    name = eqn.params.get("name")
    if not isinstance(name, str):
        nsi = eqn.params.get("name_and_src_info")
        name = getattr(nsi, "name", None)
    if prim == "pjit" and name:
        return f"pjit:{name}"
    if prim == "pallas_call":
        return f"pallas:{name}" if name else "pallas"
    if prim == "scan":
        return f"scan[{eqn.params.get('length', '?')}]"
    return f"{prim}:{name}" if name else prim


class _Walker:
    def __init__(self):
        self.sites: list[Site] = []
        self.warnings: list[str] = []
        self._warned: set[str] = set()

    def _warn(self, msg: str):
        if msg not in self._warned:
            self._warned.add(msg)
            self.warnings.append(msg)

    def walk(self, jaxpr, const_in, taint_in, mult, path, in_pallas):
        # per-var flags within this jaxpr
        const: dict[Any, bool] = {}
        taint: dict[Any, bool] = {}
        for v, c in zip(jaxpr.invars, const_in):
            const[v] = bool(c)
        for v, t in zip(jaxpr.invars, taint_in):
            taint[v] = bool(t) or _aval_is_int_code(v)
        for v in jaxpr.constvars:
            const[v] = True
            taint[v] = _aval_is_int_code(v)

        def is_const(atom):
            if isinstance(atom, jcore.Literal):
                return True
            return const.get(atom, False)

        def is_tainted(atom):
            if isinstance(atom, jcore.Literal):
                return False
            return taint.get(atom, False) or _aval_is_int_code(atom)

        for eqn in jaxpr.eqns:
            prim = eqn.primitive.name
            in_const = [is_const(a) for a in eqn.invars]
            in_taint = [is_tainted(a) for a in eqn.invars]
            out_const = all(in_const)
            out_taint = any(in_taint)

            if prim == "dot_general":
                both_int = in_taint[0] and in_taint[1]
                klass = "quantized" if (in_pallas and both_int) \
                    else "full_precision"
                self.sites.append(Site(
                    path, "dot", klass, mult * _dot_macs(eqn),
                    _aval_shape(eqn.outvars[0]),
                ))
            elif prim == "conv_general_dilated":
                if is_const(eqn.invars[1]):
                    klass = "data_movement"  # constant (patch/identity) filter
                elif in_pallas and in_taint[0] and in_taint[1]:
                    klass = "quantized"
                else:
                    klass = "full_precision"
                self.sites.append(Site(
                    path, "conv", klass, mult * _conv_macs(eqn),
                    _aval_shape(eqn.outvars[0]),
                ))
            else:
                subs = _sub_jaxprs(eqn.params)
                if subs:
                    self._recurse(eqn, subs, in_const, in_taint, mult, path,
                                  in_pallas)

            for v in eqn.outvars:
                const[v] = out_const
                taint[v] = out_taint or _aval_is_int_code(v)

    def _recurse(self, eqn, subs, in_const, in_taint, mult, path, in_pallas):
        prim = eqn.primitive.name
        scope = _scope_name(eqn)
        sub_path = f"{path}/{scope}" if path else scope
        sub_mult = mult
        sub_pallas = in_pallas

        if prim == "pallas_call":
            grid = tuple(getattr(eqn.params.get("grid_mapping"), "grid", ()) or ())
            sub_mult = mult * (_prod(grid) if grid else 1)
            sub_pallas = True
            # kernel refs don't map 1:1 onto outer operands (outputs/scratch
            # are refs too); taint is re-seeded from the refs' dtypes.
            in_const, in_taint = [], []
        elif prim == "scan":
            sub_mult = mult * int(eqn.params.get("length", 1))
        elif prim == "while":
            self._warn(
                "while-loop encountered: trip count is not static, its body "
                "FLOPs are counted once"
            )
        elif prim == "cond":
            self._warn(
                "cond encountered: all branches counted (upper bound)"
            )
            # branch jaxprs see the operands minus the predicate
            in_const = in_const[1:]
            in_taint = in_taint[1:]

        for _, sub in subs:
            n = len(sub.invars)
            c = (in_const + [False] * n)[:n]
            t = (in_taint + [False] * n)[:n]
            self.walk(sub, c, t, sub_mult, sub_path, sub_pallas)


def coverage_of_jaxpr(closed: jcore.ClosedJaxpr) -> CoverageReport:
    """Classify every dot/conv MAC of an already-traced ``ClosedJaxpr``."""
    w = _Walker()
    n = len(closed.jaxpr.invars)
    w.walk(closed.jaxpr, [False] * n, [False] * n, 1, "", False)
    return CoverageReport(w.sites, w.warnings)


def trace_coverage(fn, *args, **kwargs) -> CoverageReport:
    """Trace ``fn`` (no execution — ``ShapeDtypeStruct`` args are fine) and
    audit its jaxpr."""
    closed = jax.make_jaxpr(fn)(*args, **kwargs)
    return coverage_of_jaxpr(closed)
