"""Quantization-coverage audit CLI.

Traces the CIFAR low-bit train step and/or the LM serve decode step (no
execution — abstract inputs only), classifies every dot/conv MAC as
quantized-domain vs full-precision vs data-movement, lints every shipped
``QuantConfig`` for numerics legality, AOT-compiles the compressed gradient
ring to audit its wire bytes, and writes a machine-readable
``AUDIT_report.json``.  With ``--gate`` (the CI mode) the report is checked
against the committed baseline in ``analysis/baselines/gate.json`` and the
process exits non-zero on any regression.

    PYTHONPATH=src python -m repro.analysis.audit --graph all --gate

``--kernels`` adds the Pallas kernel static verifier
(:mod:`repro.analysis.kernel_verify`): every ``KERNEL_REGISTRY`` entry is
traced and proven for grid/index-map coverage and ``< 2^24`` integer
accumulation, gated against ``analysis/baselines/kernels.json``.  It also
re-proves every winner in the committed autotuning seed cache
(``kernels/tuned/kernel_tune.json``) and fails the gate when the cache is
stale or missing a registry tuning spec:

    PYTHONPATH=src python -m repro.analysis.audit --kernels --graph none --gate

``--sabotage MODE`` plants a negative control that must make the gate
fail (exercised by the regression tests): ``fp32_gemm`` (an fp32 GEMM on
the train hot path), ``overlap_write`` (a kernel whose output index map
writes one block from conflicting grid steps), ``deep_k`` (a contraction
tile whose integer accumulator exceeds 24 bits), or ``drop_halo`` (an
implicit-conv window grid whose halo band is one row short of its taps).
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys

_BASELINE = pathlib.Path(__file__).parent / "baselines" / "gate.json"
_KERNELS_BASELINE = (
    pathlib.Path(__file__).parent / "baselines" / "kernels.json")


def _force_host_devices(n: int) -> None:
    """Must run before JAX initializes its backend (lazy, so safe here as
    long as no jax API touched devices yet in this process)."""
    flag = f"--xla_force_host_platform_device_count={n}"
    cur = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in cur:
        os.environ["XLA_FLAGS"] = f"{cur} {flag}".strip()


def build_report(
    graphs: tuple = ("train", "serve"),
    backend: str = "pallas",
    train_arch: str = "resnet20",
    serve_arch: str = "qwen2-72b",
    sabotage: str | None = None,
    wire: bool = True,
    kernels: bool = False,
) -> dict:
    from repro.analysis.coverage import coverage_of_jaxpr
    from repro.analysis.lint import lint_quant_config, lint_shipped_presets
    from repro.analysis.graphs import cifar_train_graph, serve_decode_graph
    from repro.core import FMT_CIFAR, QuantConfig

    report: dict = {"version": 1, "graphs": {}}

    built = []
    if "train" in graphs:
        g = cifar_train_graph(backend=backend, arch=train_arch,
                              sabotage=sabotage == "fp32_gemm")
        built.append((g, QuantConfig(fmt=FMT_CIFAR, backend=backend,
                                     pallas_interpret=True)))
    if "serve" in graphs:
        from repro.configs import get_smoke_config
        import dataclasses

        cfg = dataclasses.replace(get_smoke_config(serve_arch),
                                  quant_backend=backend)
        built.append((serve_decode_graph(backend=backend, arch=serve_arch),
                      cfg.qcfg()))

    for g, qcfg in built:
        cov = coverage_of_jaxpr(g.jaxpr())
        entry = {
            **g.meta,
            "coverage": cov.to_json(),
            "lint": lint_quant_config(qcfg).to_json(),
        }
        report["graphs"][g.name] = entry

    report["presets"] = {
        arch: res.to_json() for arch, res in lint_shipped_presets().items()
    }

    if wire:
        from repro.analysis.wire import audit_wire_ring

        report["wire_ring"] = audit_wire_ring()

    if kernels:
        from repro.analysis.kernel_verify import run_kernel_audit
        from repro.kernels.autotune import (
            SEED_CACHE_PATH, TuneCache, check_cache)

        kernel_sabotage = sabotage if sabotage in (
            "overlap_write", "deep_k", "drop_halo") else None
        report["kernels"] = run_kernel_audit(sabotage=kernel_sabotage)
        # Tuning-cache staleness: the committed seed cache must cover every
        # registry tuning spec and every seeded winner must still prove
        # legal against the current kernels.
        report["tune_cache"] = check_cache(TuneCache.load(SEED_CACHE_PATH))

    return report


def apply_gate(report: dict, baseline: dict) -> list[str]:
    """Returns the list of gate failures (empty = pass)."""
    failures = []
    for name, min_frac in baseline.get("min_quantized_fraction", {}).items():
        entry = report["graphs"].get(name)
        if entry is None:
            continue  # graph not audited in this invocation
        frac = entry["coverage"]["quantized_fraction"]
        if frac < min_frac:
            fp_sites = entry["coverage"]["full_precision_sites"]
            culprit = fp_sites[0] if fp_sites else None
            failures.append(
                f"{name}: quantized fraction {frac:.4f} < {min_frac} "
                f"(largest fp32 site: {culprit})"
            )
    for name, entry in report["graphs"].items():
        if not entry["lint"]["ok"]:
            failures.append(f"{name}: lint errors {entry['lint']['errors']}")
    for arch, res in report.get("presets", {}).items():
        if not res["ok"]:
            failures.append(f"preset {arch}: lint errors {res['errors']}")
    wire = report.get("wire_ring")
    min_ratio = baseline.get("min_wire_compression_ratio")
    if wire is not None and min_ratio is not None:
        if wire["compression_ratio"] < min_ratio:
            failures.append(
                f"wire ring: compression ratio "
                f"{wire['compression_ratio']:.2f} < {min_ratio}"
            )
    failures += apply_kernel_gate(
        report.get("kernels"), baseline.get("kernels", {}))
    tc = report.get("tune_cache")
    if tc is not None and not tc["ok"]:
        failures += [f"tune cache: {f}" for f in tc["failures"]]
    return failures


def apply_kernel_gate(kernels: dict | None, baseline: dict) -> list[str]:
    """Gate failures from the ``--kernels`` static-verifier section."""
    if kernels is None:
        return []
    failures = []
    reports = kernels.get("kernels", {})
    for name in baseline.get("require_kernels", []):
        if name not in reports:
            failures.append(f"kernel {name}: missing from verifier report")
    max_bits = baseline.get("max_integer_accumulation_bits")
    for name, rep in reports.items():
        for call in rep.get("calls", []):
            for v in call.get("violations", []):
                failures.append(
                    f"kernel {name} ({call['kernel']}): {v['kind']} "
                    f"violation at {v['where']}: {v['detail']}"
                )
        bits = rep.get("max_integer_accumulation_bits", 0)
        if max_bits is not None and bits > max_bits:
            failures.append(
                f"kernel {name}: integer accumulation spans {bits} bits > "
                f"baseline {max_bits}"
            )
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.audit", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    ap.add_argument("--graph", choices=["train", "serve", "all", "none"],
                    default="all")
    ap.add_argument("--backend", choices=["pallas", "fake_quant"],
                    default="pallas")
    ap.add_argument("--train-arch", default="resnet20")
    ap.add_argument("--serve-arch", default="qwen2-72b")
    ap.add_argument("--out", default="AUDIT_report.json")
    ap.add_argument("--baseline", default=str(_BASELINE))
    ap.add_argument("--gate", action="store_true",
                    help="check against the baseline; exit 1 on regression")
    ap.add_argument("--no-wire", action="store_true",
                    help="skip the collective wire-byte audit")
    ap.add_argument("--kernels", action="store_true",
                    help="run the Pallas kernel static verifier (coverage "
                         "proofs + interval overflow prover) over "
                         "KERNEL_REGISTRY")
    ap.add_argument("--kernels-baseline", default=str(_KERNELS_BASELINE))
    ap.add_argument("--sabotage", nargs="?", const="fp32_gemm", default=None,
                    choices=["fp32_gemm", "overlap_write", "deep_k",
                             "drop_halo"],
                    help="plant a negative control the gate must fail: an "
                         "fp32 GEMM on the train hot path, an overlapping "
                         "output index map, a >24-bit contraction tile, or "
                         "an implicit-conv halo band one row short")
    args = ap.parse_args(argv)

    _force_host_devices(2)

    graphs = () if args.graph == "none" else (
        ("train", "serve") if args.graph == "all" else (args.graph,))
    report = build_report(
        graphs=graphs, backend=args.backend, train_arch=args.train_arch,
        serve_arch=args.serve_arch, sabotage=args.sabotage,
        wire=not args.no_wire, kernels=args.kernels,
    )

    with open(args.baseline) as f:
        baseline = json.load(f)
    if args.kernels:
        with open(args.kernels_baseline) as f:
            baseline["kernels"] = json.load(f)
    failures = apply_gate(report, baseline)
    report["gate"] = {
        "pass": not failures, "failures": failures,
        "baseline": baseline, "enforced": bool(args.gate),
    }

    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)

    for name, entry in report["graphs"].items():
        cov = entry["coverage"]
        print(f"{name}: quantized {100 * cov['quantized_fraction']:.2f}% "
              f"({cov['quantized_macs']:,} q / "
              f"{cov['full_precision_macs']:,} fp / "
              f"{cov['data_movement_macs']:,} dm MACs), "
              f"lint {'OK' if entry['lint']['ok'] else 'FAIL'}")
    if "wire_ring" in report:
        w = report["wire_ring"]
        print(f"wire ring: {w['compression_ratio']:.2f}x vs fp32 "
              f"({w['wire_bytes_per_device']:.0f} B/device)")
    if "kernels" in report:
        ks = report["kernels"]
        for name, rep in ks["kernels"].items():
            print(f"kernel {name}: "
                  f"{'OK' if rep['ok'] else 'FAIL'} "
                  f"({rep['num_pallas_calls']} pallas_call(s), max int "
                  f"accumulation {rep['max_integer_accumulation_bits']} "
                  f"bits / budget {ks['budget_bits']})")
    if "tune_cache" in report:
        tc = report["tune_cache"]
        print(f"tune cache: {'OK' if tc['ok'] else 'STALE'} "
              f"({tc['verified']} winner(s) re-verified, "
              f"{len(tc['required_specs'])} registry spec(s))")
    if failures:
        print("GATE FAILURES:", file=sys.stderr)
        for fmsg in failures:
            print(f"  - {fmsg}", file=sys.stderr)
    else:
        print("gate: PASS")
    print(f"report written to {args.out}")
    return 1 if (failures and args.gate) else 0


if __name__ == "__main__":
    sys.exit(main())
