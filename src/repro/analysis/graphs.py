"""Auditable graphs: step functions paired with abstract (zero-allocation)
inputs, ready for ``jax.make_jaxpr`` tracing by the coverage auditor.

Two graph families:

* ``cifar_train_graph`` — one full low-bit training step (loss, grads, SGD
  update) of a paper CNN on CIFAR shapes, with all three training GEMMs per
  conv routed through the configured backend.  ``sabotage=True`` plants an
  fp32 ``dot_general`` on the hot path (folded into the loss so it cannot be
  dead-code-eliminated) — the negative control proving the auditor and the
  CI gate actually catch unquantized compute.
* ``serve_decode_graph`` — one incremental decode step of a smoke-sized LM
  against a filled cache, quantized matmuls on the chosen backend.

All inputs are ``ShapeDtypeStruct``/``eval_shape`` abstractions — nothing is
allocated or executed, so full-size graphs trace in seconds on any host.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import FMT_CIFAR, QuantConfig

__all__ = ["AuditGraph", "cifar_train_graph", "serve_decode_graph"]


@dataclasses.dataclass
class AuditGraph:
    name: str
    fn: Any  # callable(*args)
    args: tuple  # abstract inputs for jax.make_jaxpr
    meta: dict

    def jaxpr(self):
        return jax.make_jaxpr(self.fn)(*self.args)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def cifar_train_graph(
    backend: str = "pallas",
    arch: str = "resnet20",
    batch: int = 4,
    width_mult: float = 1.0,
    in_hw: int = 32,
    sabotage: bool = False,
) -> AuditGraph:
    """Full CIFAR train step: cross-entropy loss -> grads -> SGD update.

    ``batch`` does not change the quantized fraction (every site scales
    linearly with it), so a small batch keeps tracing fast while the
    reported coverage equals the production value.
    """
    from repro.models.cnn import CNNConfig, init_cnn, apply_cnn

    cnn_cfg = CNNConfig(arch=arch, num_classes=10, width_mult=width_mult,
                        in_hw=in_hw)
    qcfg = QuantConfig(fmt=FMT_CIFAR, stochastic=True, backend=backend,
                       pallas_interpret=True)

    def train_step(params, x, y):
        def loss_fn(p):
            key = jax.random.key(0)
            logits = apply_cnn(p, x, cnn_cfg, qcfg, key)
            logp = jax.nn.log_softmax(logits)
            loss = -jnp.take_along_axis(logp, y[:, None], axis=1).mean()
            if sabotage:
                # An unquantized fp32 GEMM sneaked onto the hot path; the
                # tiny weight keeps the loss value intact while the MACs
                # stay in the traced graph (they feed the returned loss).
                h = x.reshape(x.shape[0], -1)
                loss = loss + 1e-12 * jnp.dot(h.T, h).sum()
            return loss

        loss, grads = jax.value_and_grad(loss_fn)(params)
        new_params = jax.tree.map(lambda p, g: p - 0.1 * g, params, grads)
        return loss, new_params

    params = jax.eval_shape(lambda k: init_cnn(k, cnn_cfg), jax.random.key(0))
    args = (
        params,
        _sds((batch, cnn_cfg.in_ch, in_hw, in_hw), jnp.float32),
        _sds((batch,), jnp.int32),
    )
    return AuditGraph(
        name=f"train:{arch}", fn=train_step, args=args,
        meta={"kind": "train", "model": arch, "backend": backend,
              "batch": batch, "in_hw": in_hw, "width_mult": width_mult,
              "sabotage": sabotage},
    )


def serve_decode_graph(
    backend: str = "pallas",
    arch: str = "qwen2-72b",
    batch: int = 4,
    cache_len: int = 128,
) -> AuditGraph:
    """One LM decode step (smoke-sized config) against a filled cache."""
    from repro.configs import ShapeConfig, get_smoke_config
    from repro.launch.specs import abstract_params, batch_specs, cache_specs
    from repro.models import lm

    cfg = dataclasses.replace(get_smoke_config(arch), quant_backend=backend)
    shape = ShapeConfig("decode_audit", cache_len, batch, "decode")

    def decode(params, cache, tokens):
        return lm.decode_step(params, cache, tokens, cfg)

    args = (
        abstract_params(cfg),
        cache_specs(cfg, shape),
        batch_specs(cfg, shape)["tokens"],
    )
    return AuditGraph(
        name=f"serve:{arch}", fn=decode, args=args,
        meta={"kind": "serve", "model": arch, "backend": backend,
              "batch": batch, "cache_len": cache_len},
    )
