"""Static quantization auditing: jaxpr coverage, numerics lint, HLO wire
budgets.  CLI: ``python -m repro.analysis.audit --help``."""
from .coverage import CoverageReport, Site, coverage_of_jaxpr, trace_coverage
from .hlo_parser import analyze_hlo, computation_multipliers, split_computations
from .lint import LintResult, check_format_pair, lint_quant_config

__all__ = [
    "CoverageReport",
    "LintResult",
    "Site",
    "analyze_hlo",
    "check_format_pair",
    "computation_multipliers",
    "coverage_of_jaxpr",
    "lint_quant_config",
    "split_computations",
    "trace_coverage",
]
