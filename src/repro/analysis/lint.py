"""Numerics legality lint for ``QuantConfig``/``EMFormat`` pairs.

Static checks that a quantization configuration can execute *exactly* on the
arithmetic the kernels assume — the invariants the module docstrings of
``kernels/mls_matmul.py`` and ``core/quantize.py`` document but (until this
lint) nothing verified:

* **Accumulator exactness** — the quantized-domain GEMM accumulates integer
  products in fp32, which is exact only below 2^24.  A product of two
  ⟨E,M⟩ values spans ``product_bits = 2M + 2^(E+1) - 2`` bits and a scaling
  group sums ``k_block`` of them, so we require
  ``product_bits + ceil(log2(k_block)) < 24``.
* **Code width** — packed codes (sign ⊕ exponent ⊕ mantissa) must fit the
  uint8 wire/VMEM layout: ``1 + E + M <= 8``.
* **Pallas tiling** — ``k_block`` is the contraction BlockSpec tile of
  ``mls_matmul_pallas``; it must be a power of two in [16, 512] so group
  boundaries can coincide with MXU/VMEM tiles, with a warning when it is not
  a multiple of the 128-wide TPU lane.
* **Grouping / group-scale format** — grouping spec must name a known
  layout; the group-scale fraction must stay within the shift-add budget of
  the inter-group combine (``Mg <= 2``: at most 3 shifted adds per scale).
  All four Table IV groupings are first-class kernel parameters on both
  backends (the Pallas GEMM consumes each layout's compact group scales).

Everything here is pure Python on dataclass fields — safe to run in CI
without an accelerator.
"""
from __future__ import annotations

import dataclasses

from repro.core.formats import EMFormat, accumulation_bits
from repro.core.lowbit import QuantConfig

__all__ = [
    "LintResult",
    "check_format_pair",
    "lint_quant_config",
    "lint_shipped_presets",
]

_VALID_GROUPINGS = ("nc", "c", "n", "none")


@dataclasses.dataclass
class LintResult:
    errors: list[str]
    warnings: list[str]

    @property
    def ok(self) -> bool:
        return not self.errors

    def to_json(self) -> dict:
        return {"ok": self.ok, "errors": self.errors,
                "warnings": self.warnings}


def check_format_pair(fmt: EMFormat, k_block: int) -> list[str]:
    """Errors for an element format × accumulation depth pair."""
    errors = []
    if k_block < 1:
        errors.append(f"k_block must be >= 1, got {k_block}")
        return errors
    acc = accumulation_bits(fmt, k_block)
    if acc >= 24:
        errors.append(
            f"accumulating {k_block} products of {fmt} values needs {acc} "
            f"integer bits (product_bits={fmt.product_bits} + "
            f"ceil(log2(k_block))) >= 24: fp32 accumulation is no longer "
            f"bit-exact — shrink k_block or the ⟨E,M⟩ format"
        )
    if fmt.element_bits > 8:
        errors.append(
            f"{fmt} needs {fmt.element_bits} storage bits per element; the "
            f"packed code layout (sign|exp|man) is uint8 — max 8"
        )
    return errors


def lint_quant_config(cfg: QuantConfig) -> LintResult:
    """Full legality lint of one ``QuantConfig``."""
    errors = list(check_format_pair(cfg.fmt, cfg.k_block))
    warnings: list[str] = []

    margin = 24 - accumulation_bits(cfg.fmt, cfg.k_block)
    if 0 < margin <= 1:
        warnings.append(
            f"only {margin} bit of fp32 accumulator headroom for "
            f"{cfg.fmt} × k_block={cfg.k_block}; a 2x deeper group would "
            f"break exactness"
        )

    if cfg.grouping not in _VALID_GROUPINGS:
        errors.append(
            f"unknown grouping {cfg.grouping!r}; expected one of "
            f"{_VALID_GROUPINGS}"
        )

    if cfg.gs_fmt.m > 2:
        errors.append(
            f"group-scale format {cfg.gs_fmt} has Mg={cfg.gs_fmt.m} > 2: the "
            f"inter-group combine budgets <= 3 shifted adds per scale "
            f"(paper Sec. V-B); use Mg in {{0, 1, 2}}"
        )
    if cfg.gs_fmt.e < 4:
        warnings.append(
            f"group-scale format {cfg.gs_fmt} spans scale ratios only down "
            f"to 2^{cfg.gs_fmt.e_min}; groups quieter than that underflow to "
            f"the denormal level"
        )

    if cfg.backend == "pallas":
        kb = cfg.k_block
        if kb & (kb - 1) != 0 or not (16 <= kb <= 512):
            errors.append(
                f"backend='pallas' needs a power-of-two k_block in "
                f"[16, 512] (contraction BlockSpec tile), got {kb}"
            )
        elif kb % 128 != 0:
            warnings.append(
                f"k_block={kb} is not a multiple of the 128-wide TPU lane; "
                f"Mosaic pads the contraction tile, wasting MXU occupancy"
            )

    if cfg.shard_ways < 1:
        errors.append(f"shard_ways must be >= 1, got {cfg.shard_ways}")
    if cfg.wire_fsdp_dim not in (None, 0, 1):
        errors.append(
            f"wire_fsdp_dim must be None, 0 or 1, got {cfg.wire_fsdp_dim}"
        )
    if cfg.packed_wire and cfg.wire_fsdp_dim is None:
        warnings.append(
            "packed_wire=True without wire_fsdp_dim: codes are packed but "
            "not pinned to the FSDP shard axis, XLA may still gather fp32"
        )

    return LintResult(errors, warnings)


def lint_shipped_presets() -> dict[str, LintResult]:
    """Lint every QuantConfig reachable from the shipped model configs."""
    from repro.configs import ARCHS, get_config

    results: dict[str, LintResult] = {}
    for arch in ARCHS:
        cfg = get_config(arch)
        results[arch] = lint_quant_config(cfg.qcfg())
    return results
