"""Frontier sweep CLI.

Run the smoke grid and gate it against the committed baseline (the CI
smoke invocation)::

    PYTHONPATH=src python -m repro.sweep --smoke --gate

Nightly full grid with artifacts + markdown summary::

    PYTHONPATH=src python -m repro.sweep --full --gate \
        --out BENCH_accuracy.json --markdown frontier.md

Re-gate a saved artifact without re-training (cheap negative control in
CI: a sabotaged baseline must make this exit non-zero)::

    PYTHONPATH=src python -m repro.sweep --gate --from BENCH_accuracy.json
    PYTHONPATH=src python -m repro.sweep --gate --sabotage --from BENCH_accuracy.json

Bless a new/changed grid::

    PYTHONPATH=src python -m repro.sweep --smoke --update-baseline
"""
from __future__ import annotations

import argparse
import json
import sys

from .gate import (
    BASELINE_PATH,
    SABOTAGE_MODES,
    apply_gate,
    build_baseline,
    load_baseline,
    sabotage_baseline,
)
from .grid import full_grid, smoke_grid
from .record import make_payload, write_json
from .report import frontier_table
from .runner import run_cells


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.sweep", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--smoke", action="store_true",
                      help="CI-budget grid (default)")
    mode.add_argument("--full", action="store_true", help="nightly grid")
    ap.add_argument("--only", default=None, metavar="SUBSTR",
                    help="run only cells whose id contains SUBSTR "
                         "(error if nothing matches)")
    ap.add_argument("--list", action="store_true",
                    help="print the grid cells and exit")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="write rows as a BENCH_accuracy.json artifact")
    ap.add_argument("--from", dest="from_path", default=None, metavar="PATH",
                    help="gate/report a saved artifact instead of training")
    ap.add_argument("--markdown", default=None, metavar="PATH",
                    help="write the frontier markdown table here")
    ap.add_argument("--baseline", default=str(BASELINE_PATH))
    ap.add_argument("--gate", action="store_true",
                    help="check against the baseline; exit 1 on regression")
    ap.add_argument("--sabotage", nargs="?", const="regress", default=None,
                    choices=list(SABOTAGE_MODES),
                    help="corrupt the baseline in-memory: the gate MUST "
                         "fail on a healthy run (negative control)")
    ap.add_argument("--update-baseline", action="store_true",
                    help=f"bless this run into {BASELINE_PATH}")
    args = ap.parse_args(argv)

    grid_name = "full" if args.full else "smoke"

    if args.from_path:
        with open(args.from_path) as f:
            payload = json.load(f)
        rows = payload["rows"]
        grid_name = payload.get("grid", grid_name)
    else:
        cells = full_grid() if args.full else smoke_grid()
        if args.only:
            cells = [c for c in cells if args.only in c.cell_id()]
            if not cells:
                grid = full_grid() if args.full else smoke_grid()
                print(f"--only {args.only!r} matches no cell; have:\n  "
                      + "\n  ".join(c.cell_id() for c in grid),
                      file=sys.stderr)
                return 2
            grid_name = None  # partial run: skip reverse-coverage gating
        if args.list:
            for c in cells:
                print(f"{c.cell_id()}  hash={c.config_hash()}  steps={c.steps}")
            return 0
        rows = run_cells(cells)
        payload = make_payload("frontier_sweep", rows,
                               quick=not args.full,
                               extra={"grid": grid_name or "partial"})

    if args.out:
        write_json(args.out, payload)

    md = frontier_table(
        rows, title=f"Bit-width × architecture frontier ({grid_name or 'partial'} grid)")
    if args.markdown:
        with open(args.markdown, "w") as f:
            f.write(md)
        print(f"wrote {args.markdown}")
    else:
        print(md)

    if args.update_baseline:
        if args.sabotage:
            print("refusing to --update-baseline under --sabotage", file=sys.stderr)
            return 2
        if grid_name is None:
            print("refusing to --update-baseline from a partial (--only) run",
                  file=sys.stderr)
            return 2
        try:
            existing = load_baseline(args.baseline)
        except FileNotFoundError:
            existing = None
        with open(args.baseline, "w") as f:
            json.dump(build_baseline(rows, grid_name, existing), f, indent=2)
            f.write("\n")
        print(f"baseline updated: {args.baseline}")
        return 0

    baseline = load_baseline(args.baseline)
    if args.sabotage:
        baseline = sabotage_baseline(baseline, args.sabotage)
    failures = apply_gate(rows, baseline, grid_name=grid_name)
    if failures:
        print("GATE FAILURES:", file=sys.stderr)
        for fmsg in failures:
            print(f"  - {fmsg}", file=sys.stderr)
    else:
        print("gate: PASS")
    return 1 if (failures and args.gate) else 0


if __name__ == "__main__":
    sys.exit(main())
