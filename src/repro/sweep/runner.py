"""Convergence-proxy runner: one short training run per frontier cell.

CNN cells train the reduced paper models (``models/cnn.py``) on the
synthetic CIFAR stream with the paper's SGD-momentum recipe — the same
proxy ``benchmarks/table2_accuracy.py`` reports.  LM cells train the
reduced smoke configs of the assigned architectures (``models/lm.py``:
dense transformer / Mamba2 SSD / MoE) on the synthetic Markov token stream
with AdamW.  Everything is seeded from the cell, so a cell's metrics are
deterministic given the software stack — which is what lets the gate hold
tight per-cell tolerances against a committed baseline.
"""
from __future__ import annotations

import dataclasses
import math
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core import QuantConfig
from repro.data import make_cifar_iterator, make_lm_iterator
from repro.models import lm
from repro.models.cnn import CNNConfig, apply_cnn, init_cnn
from repro.optim import adamw_init, adamw_update, sgdm_init, sgdm_update

from .grid import LM_ARCHS, Cell

__all__ = ["run_cell", "run_cells"]

_LM_LR = 1e-3
_NUM_CLASSES = 10

# A proxy has diverged when its trailing loss exceeds this multiple of the
# uniform-prediction loss (ln(classes) / ln(vocab)) — or goes non-finite.
_DIVERGENCE_MULT = 2.0


def _tail_mean(xs: list[float]) -> float:
    k = max(1, len(xs) // 5)
    return sum(xs[-k:]) / k


def _train_cnn(cell: Cell) -> tuple[float, float | None]:
    cfg = CNNConfig(arch=cell.arch, num_classes=_NUM_CLASSES,
                    width_mult=cell.width, in_hw=cell.hw)
    qcfg = None
    if cell.emformat is not None:
        qcfg = QuantConfig(fmt=cell.emformat, grouping=cell.grouping,
                           backend=cell.backend)
    params = init_cnn(jax.random.key(cell.seed), cfg)
    opt = sgdm_init(params)
    nxt, ds = make_cifar_iterator(batch=cell.batch, hw=cell.hw,
                                  num_classes=_NUM_CLASSES, seed=cell.seed)

    @jax.jit
    def step(params, opt, batch, i):
        def loss_fn(p):
            logits = apply_cnn(p, batch["image"], cfg, qcfg,
                               jax.random.fold_in(jax.random.key(1), i))
            ll = jax.nn.log_softmax(logits)
            loss = -jnp.take_along_axis(ll, batch["label"][:, None], 1).mean()
            acc = (logits.argmax(-1) == batch["label"]).mean()
            return loss, acc

        (l, a), g = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt = sgdm_update(g, opt, params, lr=cell.lr)
        return params, opt, l, a

    losses, accs = [], []
    for i in range(cell.steps):
        batch, ds = nxt(ds)
        params, opt, l, a = step(params, opt, batch, jnp.int32(i))
        losses.append(float(l))
        accs.append(float(a))
    return _tail_mean(losses), _tail_mean(accs)


def _train_lm(cell: Cell) -> tuple[float, float | None]:
    cfg = get_smoke_config(LM_ARCHS[cell.arch])
    cfg = dataclasses.replace(
        cfg,
        quant=cell.emformat is not None,
        fmt=cell.emformat if cell.emformat is not None else cfg.fmt,
        quant_backend=cell.backend,
    )
    p = lm.init_lm(jax.random.key(cell.seed), cfg)
    opt = adamw_init(p)
    extras = ()
    if cfg.frontend != "none" and cfg.family != "encdec":
        extras = (("frontend_emb",
                   (cell.batch, cfg.frontend_len, cfg.frontend_dim)),)
    nxt, ds = make_lm_iterator(cell.batch, cell.seq, cfg.vocab,
                               seed=cell.seed, extras=extras)

    @jax.jit
    def step(p, opt, batch, i):
        (l, _), g = jax.value_and_grad(lm.lm_loss, has_aux=True)(
            p, batch, cfg, jax.random.fold_in(jax.random.key(1), i))
        p, opt = adamw_update(g, opt, p, lr=_LM_LR)
        return p, opt, l

    losses = []
    for i in range(cell.steps):
        batch, ds = nxt(ds)
        p, opt, l = step(p, opt, batch, jnp.int32(i))
        losses.append(float(l))
    return _tail_mean(losses), None


def divergence_threshold(cell: Cell) -> float:
    if cell.is_cnn:
        return _DIVERGENCE_MULT * math.log(_NUM_CLASSES)
    return _DIVERGENCE_MULT * math.log(get_smoke_config(LM_ARCHS[cell.arch]).vocab)


def run_cell(cell: Cell) -> dict:
    """Train one cell; return its BENCH_accuracy.json row."""
    t0 = time.perf_counter()
    final_loss, final_acc = (_train_cnn if cell.is_cnn else _train_lm)(cell)
    wall = time.perf_counter() - t0
    diverged = (not math.isfinite(final_loss)
                or final_loss > divergence_threshold(cell))
    row = {
        "name": f"sweep/{cell.cell_id()}",
        "cell_id": cell.cell_id(),
        "config_hash": cell.config_hash(),
        "arch": cell.arch,
        "fmt": cell.fmt,
        "backend": cell.backend,
        "grouping": cell.grouping,
        "steps": cell.steps,
        "final_loss": round(final_loss, 6) if math.isfinite(final_loss) else None,
        "final_acc": None if final_acc is None else round(final_acc, 6),
        "diverged": bool(diverged),
        "wall_time_s": round(wall, 2),
    }
    if cell.envelope_acc is not None:
        row["envelope_acc"] = cell.envelope_acc
    if cell.envelope_loss is not None:
        row["envelope_loss"] = cell.envelope_loss
    return row


def run_cells(cells: list[Cell], verbose: bool = True) -> list[dict]:
    rows = []
    for i, cell in enumerate(cells):
        row = run_cell(cell)
        rows.append(row)
        if verbose:
            loss = row["final_loss"]
            acc = row["final_acc"]
            print(f"[{i + 1}/{len(cells)}] {row['cell_id']}: "
                  f"loss={'nan' if loss is None else f'{loss:.3f}'}"
                  + ("" if acc is None else f" acc={acc:.3f}")
                  + (" DIVERGED" if row["diverged"] else "")
                  + f" ({row['wall_time_s']:.1f}s)", flush=True)
    return rows
