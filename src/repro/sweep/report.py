"""Markdown frontier table for ``$GITHUB_STEP_SUMMARY`` and local runs.

The pivot view is the paper's Tables II–IV shape: one row per
(architecture, backend, grouping), one column per ``<E,M>`` format, so a
glance at the nightly job summary shows the accuracy/bit-width surface and
any newly diverged cell.
"""
from __future__ import annotations

from .grid import FORMATS

__all__ = ["frontier_table"]


def _fmt_metric(row: dict) -> str:
    if row["diverged"]:
        return "**DIVERGED**"
    if row["final_acc"] is not None:
        return f"acc {row['final_acc']:.3f}"
    if row["final_loss"] is not None:
        return f"loss {row['final_loss']:.3f}"
    return "n/a"


def frontier_table(rows: list[dict], title: str = "Bit-width × architecture frontier") -> str:
    """Render rows (runner output) as a markdown pivot + detail table."""
    fmts = [f for f in FORMATS if any(r["fmt"] == f for r in rows)]
    groups: dict[tuple[str, str, str], dict[str, dict]] = {}
    for r in rows:
        groups.setdefault((r["arch"], r["backend"], r["grouping"]), {})[r["fmt"]] = r

    lines = [f"### {title}", ""]
    lines.append("| arch | backend | " + " | ".join(f"`{f}`" for f in fmts) + " |")
    lines.append("|---|---|" + "---|" * len(fmts))
    for (arch, backend, grouping), by_fmt in groups.items():
        label = arch if grouping == "nc" else f"{arch} (grouping={grouping})"
        cells = [_fmt_metric(by_fmt[f]) if f in by_fmt else "—" for f in fmts]
        lines.append(f"| {label} | {backend} | " + " | ".join(cells) + " |")

    lines += ["", "<details><summary>per-cell detail</summary>", ""]
    lines.append("| cell | hash | loss | acc | steps | wall (s) |")
    lines.append("|---|---|---|---|---|---|")
    for r in rows:
        loss = "—" if r["final_loss"] is None else f"{r['final_loss']:.4f}"
        acc = "—" if r["final_acc"] is None else f"{r['final_acc']:.4f}"
        lines.append(
            f"| `{r['cell_id']}` | `{r['config_hash']}` | {loss} | {acc} "
            f"| {r['steps']} | {r['wall_time_s']:.1f} |")
    lines += ["", "</details>", ""]
    return "\n".join(lines)
