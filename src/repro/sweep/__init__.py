"""Bit-width × architecture frontier sweep (ROADMAP scenario-diversity item).

Drives short convergence-proxy training runs over a declarative grid of
``(<E,M> format × grouping × backend) × architecture`` cells — the paper's
Tables II–IV accuracy/bit-width trade-off surface extended beyond CNNs to
the transformer/Mamba2/MoE low-bit paths — and emits one structured
``BENCH_accuracy.json`` row per cell.  A trend gate compares the run
against the committed baseline (``sweep/baselines/accuracy.json``) with
per-cell tolerances so convergence regressions fail CI instead of staying
anecdotal::

    PYTHONPATH=src python -m repro.sweep --smoke --gate

See :mod:`repro.sweep.grid` for the cell schema, :mod:`repro.sweep.gate`
for the tolerance semantics and :mod:`repro.sweep.report` for the markdown
frontier table written to ``$GITHUB_STEP_SUMMARY`` by CI.
"""
from .gate import apply_gate, load_baseline, sabotage_baseline
from .grid import FORMATS, Cell, expand_grid, full_grid, smoke_grid
from .report import frontier_table
from .runner import run_cell, run_cells

__all__ = [
    "FORMATS",
    "Cell",
    "apply_gate",
    "expand_grid",
    "frontier_table",
    "full_grid",
    "load_baseline",
    "run_cell",
    "run_cells",
    "sabotage_baseline",
    "smoke_grid",
]
