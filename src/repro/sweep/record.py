"""``BENCH_*.json`` emission: one schema, every artifact, every commit.

Nightly CI trends ``BENCH_*.json`` artifacts across commits, which only
works if every producer (kernel bench, accuracy tables, the frontier
sweep) stamps rows identically.  This module is the single implementation;
``benchmarks/_record.py`` re-exports it for the script-side producers.

Every payload and every row carries ``schema_version`` and ``git_sha``
(``GITHUB_SHA`` in CI, ``git rev-parse`` locally, ``"unknown"`` outside a
checkout) so two artifacts are comparable without trusting filenames.
"""
from __future__ import annotations

import json
import os
import platform
import subprocess
import time

import jax

__all__ = ["SCHEMA_VERSION", "git_sha", "make_payload", "stamp_rows", "write_json"]

SCHEMA_VERSION = 1


def git_sha() -> str:
    """Current commit SHA: CI env var first, then git, else "unknown"."""
    sha = os.environ.get("GITHUB_SHA")
    if sha:
        return sha
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            check=True, timeout=10,
        ).stdout.strip()
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def stamp_rows(rows: list[dict], sha: str | None = None) -> list[dict]:
    """Stamp ``schema_version`` + ``git_sha`` into every row, in place."""
    sha = sha or git_sha()
    for r in rows:
        r.setdefault("schema_version", SCHEMA_VERSION)
        r.setdefault("git_sha", sha)
    return rows


def make_payload(suite: str, rows: list[dict], *, quick: bool | None = None,
                 extra: dict | None = None) -> dict:
    """The common artifact envelope around stamped rows."""
    payload = {
        "suite": suite,
        "schema_version": SCHEMA_VERSION,
        "git_sha": git_sha(),
        "unix_time": time.time(),
        "backend": jax.default_backend(),
        "machine": platform.machine(),
    }
    if quick is not None:
        payload["quick"] = quick
    if extra:
        payload.update(extra)
    payload["rows"] = stamp_rows(rows, sha=payload["git_sha"])
    return payload


def write_json(path: str, payload: dict) -> None:
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {path}")
