"""Trend gate: compare a sweep run against the committed accuracy baseline.

The baseline (``sweep/baselines/accuracy.json``) holds one entry per cell
``config_hash`` with the blessed convergence metrics and which grid(s) the
cell belongs to.  Gating is per-cell with explicit tolerances:

* a cell missing from the baseline fails (refresh with
  ``--update-baseline`` — new frontier cells must be blessed on purpose);
* a baseline cell of the current grid missing from the run fails (the grid
  silently shrank);
* a cell that *newly* diverges fails; a baseline-diverged cell may stay
  diverged (the paper expects pure fixed point to degrade or diverge);
* ``final_loss`` may not regress more than ``loss_tol`` and ``final_acc``
  may not drop more than ``acc_tol`` (per-cell overrides in the baseline
  entry, else the defaults below);
* envelope cells additionally compare against the same-arch fp32 cell of
  the *same run* — the paper's "<2,1> stays within 1% of fp32 on CIFAR"
  claim scaled to the short proxy's noise floor.

``sabotage_baseline`` plants a negative control (CI runs it to prove the
gate can fail): it rewrites the blessed metrics so a healthy run looks
like a regression, or drops a cell so the run looks unblessed.
"""
from __future__ import annotations

import copy
import json
import pathlib

__all__ = [
    "DEFAULT_ACC_TOL",
    "DEFAULT_LOSS_TOL",
    "SABOTAGE_MODES",
    "apply_gate",
    "build_baseline",
    "load_baseline",
    "sabotage_baseline",
]

BASELINE_PATH = pathlib.Path(__file__).parent / "baselines" / "accuracy.json"

# Metrics are deterministic on one software stack (seeded cells); the
# tolerances absorb cross-machine float reduction differences only.
DEFAULT_LOSS_TOL = 0.25
DEFAULT_ACC_TOL = 0.20

SABOTAGE_MODES = ("regress", "missing_cell")


def load_baseline(path: str | pathlib.Path | None = None) -> dict:
    with open(path or BASELINE_PATH) as f:
        return json.load(f)


def _fp32_reference(rows: list[dict], arch: str) -> dict | None:
    """The same-run fp32 fake-quant cell every envelope is measured against."""
    for r in rows:
        if (r["arch"] == arch and r["fmt"] == "fp32"
                and r["backend"] == "fake_quant" and r["grouping"] == "nc"):
            return r
    return None


def apply_gate(rows: list[dict], baseline: dict,
               grid_name: str | None = None) -> list[str]:
    """Return the list of human-readable gate failures (empty = pass)."""
    failures: list[str] = []
    cells = baseline.get("cells", {})
    by_hash = {r["config_hash"]: r for r in rows}

    for r in rows:
        cid, h = r["cell_id"], r["config_hash"]
        base = cells.get(h)
        if base is None:
            failures.append(
                f"{cid}: cell {h} not in baseline — bless new/changed cells "
                f"with `python -m repro.sweep --update-baseline`")
            continue
        loss_tol = base.get("loss_tol", DEFAULT_LOSS_TOL)
        acc_tol = base.get("acc_tol", DEFAULT_ACC_TOL)
        if r["diverged"] and not base.get("diverged", False):
            failures.append(
                f"{cid}: newly diverged (loss={r['final_loss']}, "
                f"baseline loss={base.get('final_loss')})")
            continue
        if (r["final_loss"] is not None and base.get("final_loss") is not None
                and r["final_loss"] > base["final_loss"] + loss_tol):
            failures.append(
                f"{cid}: final_loss {r['final_loss']:.4f} regressed past "
                f"baseline {base['final_loss']:.4f} + tol {loss_tol}")
        if (r["final_acc"] is not None and base.get("final_acc") is not None
                and r["final_acc"] < base["final_acc"] - acc_tol):
            failures.append(
                f"{cid}: final_acc {r['final_acc']:.4f} regressed past "
                f"baseline {base['final_acc']:.4f} - tol {acc_tol}")

    # reverse coverage: the current grid may not silently lose blessed cells
    if grid_name is not None:
        for h, base in cells.items():
            if grid_name in base.get("grids", ()) and h not in by_hash:
                failures.append(
                    f"{base.get('cell_id', h)}: baseline cell {h} of grid "
                    f"'{grid_name}' missing from the run (grid shrank — "
                    f"refresh the baseline if intentional)")

    # paper-envelope checks against the same run's fp32 reference cells
    for r in rows:
        env_acc, env_loss = r.get("envelope_acc"), r.get("envelope_loss")
        if env_acc is None and env_loss is None:
            continue
        if r["fmt"] == "fp32":
            continue  # the reference itself
        ref = _fp32_reference(rows, r["arch"])
        if ref is None:
            failures.append(
                f"{r['cell_id']}: envelope requested but no fp32 reference "
                f"cell for arch {r['arch']} in this run")
            continue
        if (env_acc is not None and r["final_acc"] is not None
                and ref["final_acc"] is not None
                and r["final_acc"] < ref["final_acc"] - env_acc):
            failures.append(
                f"{r['cell_id']}: final_acc {r['final_acc']:.4f} fell out of "
                f"the fp32 envelope ({ref['final_acc']:.4f} - {env_acc})")
        if (env_loss is not None and r["final_loss"] is not None
                and ref["final_loss"] is not None
                and r["final_loss"] > ref["final_loss"] + env_loss):
            failures.append(
                f"{r['cell_id']}: final_loss {r['final_loss']:.4f} fell out "
                f"of the fp32 envelope ({ref['final_loss']:.4f} + {env_loss})")
    return failures


def build_baseline(rows: list[dict], grid_name: str,
                   existing: dict | None = None) -> dict:
    """Merge a run into the baseline: bless this grid's cells, keep the
    other grid's entries and any per-cell tolerance overrides untouched."""
    out = copy.deepcopy(existing) if existing else {"schema_version": 1, "cells": {}}
    cells = out.setdefault("cells", {})
    # drop stale entries of this grid that the current grid no longer has
    current = {r["config_hash"] for r in rows}
    for h in list(cells):
        grids = set(cells[h].get("grids", ()))
        if grid_name in grids and h not in current:
            grids.discard(grid_name)
            if not grids:
                del cells[h]
            else:
                cells[h]["grids"] = sorted(grids)
    for r in rows:
        prev = cells.get(r["config_hash"], {})
        entry = {
            "cell_id": r["cell_id"],
            "grids": sorted(set(prev.get("grids", ())) | {grid_name}),
            "final_loss": r["final_loss"],
            "final_acc": r["final_acc"],
            "diverged": r["diverged"],
        }
        for tol in ("loss_tol", "acc_tol"):  # preserve manual overrides
            if tol in prev:
                entry[tol] = prev[tol]
        cells[r["config_hash"]] = entry
    return out


def sabotage_baseline(baseline: dict, mode: str = "regress") -> dict:
    """Negative control: corrupt the baseline so a healthy run MUST fail."""
    if mode not in SABOTAGE_MODES:
        raise ValueError(f"unknown sabotage mode {mode!r}; have {SABOTAGE_MODES}")
    out = copy.deepcopy(baseline)
    cells = out.get("cells", {})
    if not cells:
        raise ValueError("cannot sabotage an empty baseline")
    if mode == "missing_cell":
        del cells[next(iter(cells))]
        return out
    for entry in cells.values():  # "regress"
        if entry.get("final_loss") is not None:
            entry["final_loss"] -= 1.0
        if entry.get("final_acc") is not None:
            entry["final_acc"] = min(1.0, entry["final_acc"] + 0.5)
        entry["diverged"] = False
    return out
