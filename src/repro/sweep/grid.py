"""Declarative sweep grid: cells, expansion, dedup and stable config hashes.

A :class:`Cell` is one point on the accuracy/bit-width frontier: an
architecture trained for a few steps under one ``(<E,M> format, grouping,
backend)`` numerics choice.  Grids are written as *spec blocks* — dicts
whose list-valued axes are expanded as a cartesian product — so adding a
format or an architecture to the nightly surface is a one-line edit::

    {"arch": ["resnet20"], "fmt": ["fp32", "mls_e2m1"],
     "backend": ["fake_quant"], "steps": 12}

Every cell carries a ``config_hash`` over exactly the fields that change
the trained math (architecture, proxy shape, numerics, steps, seed — *not*
gate tolerances), so baseline rows stay keyed to the cell's semantics and a
silent proxy change can never be compared against a stale baseline number.
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
import json

from repro.core import EMFormat

__all__ = ["FORMATS", "Cell", "expand_grid", "full_grid", "smoke_grid"]

# The swept <E,M> element formats (paper Table II naming).  ``fp32`` is the
# unquantized reference cell every envelope is measured against.
FORMATS: dict[str, EMFormat | None] = {
    "fp32": None,
    "mls_e2m4": EMFormat(2, 4),   # <2,4>: the paper's ImageNet-scale pick
    "mls_e2m1": EMFormat(2, 1),   # <2,1>: the paper's CIFAR-scale pick
    "fix_e0m4": EMFormat(0, 4),   # fixed point, no element exponent
}

# CNN archs resolve through models/cnn.py; LM families through the smoke
# configs of these assigned architectures (models/lm.py).
LM_ARCHS = {
    "transformer": "qwen2-72b",
    "mamba2": "mamba2-370m",
    "moe": "moonshot-v1-16b-a3b",
}
CNN_ARCHS = ("resnet20", "vgg16", "googlenet")

# Fields that define the trained math — the config-hash domain.  Gate
# tolerances (envelope_*) deliberately excluded: loosening a tolerance must
# not orphan the baseline row.
_HASH_FIELDS = (
    "arch", "fmt", "backend", "grouping", "steps", "seed",
    "batch", "hw", "width", "seq", "lr",
)


@dataclasses.dataclass(frozen=True)
class Cell:
    """One frontier cell: an (arch, numerics) convergence-proxy run."""

    arch: str            # resnet20 | vgg16 | googlenet | transformer | mamba2 | moe
    fmt: str             # key into FORMATS; "fp32" disables quantization
    backend: str = "fake_quant"   # fake_quant | pallas
    grouping: str = "nc"          # paper Table IV scaling-group layout
    steps: int = 12
    seed: int = 0
    # proxy shape knobs (CNN: batch/hw/width; LM: batch/seq)
    batch: int = 16
    hw: int = 8          # CNN input resolution (vgg16 needs >= 32: 5 pools)
    width: float = 0.25  # CNN width multiplier
    seq: int = 32        # LM sequence length
    lr: float = 0.05     # sgdm lr for CNNs; LM cells use adamw 1e-3
    # Gate envelopes vs the same-arch fp32 fake_quant cell of the same run
    # (paper Table II: <2,1> stays within 1% on CIFAR at full scale; the
    # short proxy needs a looser margin).  None = no envelope (the paper
    # *expects* fixed-point Ex=0 to degrade).
    envelope_acc: float | None = None   # CNN: acc >= fp32_acc - envelope
    envelope_loss: float | None = None  # LM:  loss <= fp32_loss + envelope

    def __post_init__(self):
        if self.fmt not in FORMATS:
            raise ValueError(f"unknown format {self.fmt!r}; have {sorted(FORMATS)}")
        if self.arch not in CNN_ARCHS and self.arch not in LM_ARCHS:
            raise ValueError(
                f"unknown arch {self.arch!r}; have {sorted(CNN_ARCHS + tuple(LM_ARCHS))}")
        if self.backend not in ("fake_quant", "pallas"):
            raise ValueError(f"unknown backend {self.backend!r}")

    @property
    def is_cnn(self) -> bool:
        return self.arch in CNN_ARCHS

    @property
    def emformat(self) -> EMFormat | None:
        return FORMATS[self.fmt]

    def cell_id(self) -> str:
        """Human-readable unique id (the row ``name`` in BENCH_accuracy.json)."""
        parts = [self.arch, self.fmt, self.backend]
        if self.grouping != "nc":
            parts.append(f"g_{self.grouping}")
        return "/".join(parts)

    def config_hash(self) -> str:
        """Stable 12-hex digest of the math-defining fields (baseline key)."""
        payload = {f: getattr(self, f) for f in _HASH_FIELDS}
        blob = json.dumps(payload, sort_keys=True, separators=(",", ":"))
        return hashlib.sha256(blob.encode()).hexdigest()[:12]


def expand_grid(spec_blocks) -> list[Cell]:
    """Expand spec blocks (list-valued axes → cartesian product) into a
    deduplicated, order-preserving list of cells.

    Two blocks may overlap (e.g. a broad format sweep plus a targeted
    grouping block that repeats one format); dedup is by ``config_hash`` so
    semantically identical cells run once no matter how the spec is
    written.
    """
    cells: list[Cell] = []
    seen: set[str] = set()
    for block in spec_blocks:
        axes = {k: v if isinstance(v, list) else [v] for k, v in block.items()}
        keys = list(axes)
        for combo in itertools.product(*(axes[k] for k in keys)):
            cell = Cell(**dict(zip(keys, combo)))
            h = cell.config_hash()
            if h not in seen:
                seen.add(h)
                cells.append(cell)
    return cells


# ---------------------------------------------------------------------------
# The two committed grids.  Budget notes (CPU, interpret-mode pallas):
# fake_quant CNN ~1.2 s/step at hw=8 plus ~3 s compile; LM smoke cells
# ~1 s/step; pallas LM cells ~3-8 s/step dominated by one-off compiles; a
# pallas CNN cell compiles for minutes, so it only appears in the full grid.
# ---------------------------------------------------------------------------
_SMOKE_SPEC = [
    # CIFAR-proxy CNNs across all four formats (paper Table II axis).
    {"arch": "resnet20", "fmt": ["fp32", "mls_e2m4", "mls_e2m1", "fix_e0m4"],
     "backend": "fake_quant", "steps": 12, "batch": 16, "hw": 8,
     "envelope_acc": 0.35},
    {"arch": "vgg16", "fmt": ["fp32", "mls_e2m4", "mls_e2m1"],
     "backend": "fake_quant", "steps": 8, "batch": 8, "hw": 32,
     "width": 0.125, "envelope_acc": 0.45},
    # Beyond-paper LM families (transformer / SSM / MoE low-bit training).
    {"arch": "transformer", "fmt": ["fp32", "mls_e2m4", "mls_e2m1"],
     "backend": "fake_quant", "steps": 8, "batch": 2, "envelope_loss": 0.6},
    {"arch": "mamba2", "fmt": ["mls_e2m4"],
     "backend": "fake_quant", "steps": 8, "batch": 2},
    {"arch": "moe", "fmt": ["mls_e2m4"],
     "backend": "fake_quant", "steps": 8, "batch": 2},
    # Quantized-domain Pallas backend (interpret mode on CPU): the cheap
    # matmul-path cells keep the kernel arithmetic on the nightly frontier
    # without a minutes-long conv compile in the smoke budget.
    {"arch": "mamba2", "fmt": ["mls_e2m4", "mls_e2m1"],
     "backend": "pallas", "steps": 3, "batch": 2},
    {"arch": "transformer", "fmt": ["mls_e2m4"],
     "backend": "pallas", "steps": 3, "batch": 2},
]

_FULL_SPEC = [
    {"arch": "resnet20", "fmt": ["fp32", "mls_e2m4", "mls_e2m1", "fix_e0m4"],
     "backend": "fake_quant", "steps": 40, "batch": 16, "hw": 8,
     "envelope_acc": 0.35},
    # paper Table IV ablation axis: grouping off for the CIFAR pick
    {"arch": "resnet20", "fmt": "mls_e2m1", "grouping": "none",
     "backend": "fake_quant", "steps": 40, "batch": 16, "hw": 8},
    # quantized-domain conv kernels on the CNN path (compile-heavy: nightly only)
    {"arch": "resnet20", "fmt": "mls_e2m4", "backend": "pallas",
     "steps": 6, "batch": 8, "hw": 8},
    # lr 0.01: the paper recipe's 0.05 is unstable on the 20-step synthetic
    # vgg proxy (fp32 itself drifts; quantized cells diverge)
    {"arch": "vgg16", "fmt": ["fp32", "mls_e2m4", "mls_e2m1"],
     "backend": "fake_quant", "steps": 20, "batch": 8, "hw": 32,
     "width": 0.125, "lr": 0.01, "envelope_acc": 0.45},
    {"arch": "transformer", "fmt": ["fp32", "mls_e2m4", "mls_e2m1"],
     "backend": "fake_quant", "steps": 20, "batch": 2, "envelope_loss": 0.5},
    {"arch": "transformer", "fmt": "mls_e2m4", "backend": "pallas",
     "steps": 8, "batch": 2},
    {"arch": "mamba2", "fmt": ["fp32", "mls_e2m4", "mls_e2m1"],
     "backend": "fake_quant", "steps": 20, "batch": 2, "envelope_loss": 0.5},
    {"arch": "mamba2", "fmt": ["mls_e2m4", "mls_e2m1"], "backend": "pallas",
     "steps": 8, "batch": 2},
    {"arch": "moe", "fmt": ["fp32", "mls_e2m4", "mls_e2m1"],
     "backend": "fake_quant", "steps": 16, "batch": 2, "envelope_loss": 0.5},
]


def smoke_grid() -> list[Cell]:
    """CI-budget grid: >= 12 cells, >= 3 formats x >= 3 archs, both backends,
    < ~5 min on CPU (asserted by tests/test_sweep.py)."""
    return expand_grid(_SMOKE_SPEC)


def full_grid() -> list[Cell]:
    """Nightly grid: longer proxies, grouping ablation, pallas conv cell."""
    return expand_grid(_FULL_SPEC)
