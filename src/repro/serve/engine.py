"""Batched serving engine: prefill + incremental decode over a KV/SSM cache.

Inference uses nearest rounding (no stochastic-rounding key), per
``lm.decode_step``.  Sampling is greedy or temperature-based; generation is
jit-compiled with donated caches so decode steps run in-place.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import lm


@dataclasses.dataclass
class ServeEngine:
    cfg: ModelConfig
    params: object
    max_len: int = 4096

    def __post_init__(self):
        self._prefill = jax.jit(
            lambda p, batch: lm.prefill(p, batch, self.cfg, self.max_len)
        )
        self._decode = jax.jit(
            lambda p, cache, tok: lm.decode_step(p, cache, tok, self.cfg),
            donate_argnums=(1,),
        )

    def generate(
        self,
        batch: dict[str, jax.Array],  # {"tokens": (B, S_prompt), ...}
        max_new_tokens: int,
        temperature: float = 0.0,
        key: jax.Array | None = None,
    ) -> jax.Array:
        """Returns generated token ids (B, max_new_tokens)."""
        logits, cache = self._prefill(self.params, batch)
        toks = []
        tok = self._sample(logits, temperature, key, 0)
        toks.append(tok)
        for i in range(1, max_new_tokens):
            logits, cache = self._decode(self.params, cache, tok)
            tok = self._sample(logits, temperature, key, i)
            toks.append(tok)
        return jnp.concatenate(toks, axis=1)

    def _sample(self, logits, temperature, key, i):
        if temperature <= 0.0 or key is None:
            return jnp.argmax(logits, -1)[:, None]
        k = jax.random.fold_in(key, i)
        return jax.random.categorical(k, logits / temperature)[:, None]
