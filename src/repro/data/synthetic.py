"""Deterministic synthetic data pipelines.

The container is offline, so datasets are procedural but *learnable* —
convergence experiments need structure, not noise:

* ``cifar_like``: class-conditional Gabor-ish patterns + noise; a CNN can
  reach high accuracy, and quantization-induced degradation is measurable
  (used by the paper-reproduction benchmarks and examples).
* ``lm``: order-2 Markov token streams with a class-dependent transition
  matrix; cross-entropy drops well below uniform when the model learns.

Iterators are **stateful pytrees** (``DataState``): the current step and RNG
key live in the checkpoint, so restarts resume the exact data stream
(fault-tolerance requirement, DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DataState:
    step: jax.Array  # int32
    key: jax.Array

    def tree_flatten(self):
        return (self.step, self.key), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @staticmethod
    def init(seed: int = 0) -> "DataState":
        return DataState(jnp.int32(0), jax.random.key(seed))


# ---------------------------------------------------------------------------
# CIFAR-like images
# ---------------------------------------------------------------------------
def _class_pattern(num_classes: int, hw: int) -> jax.Array:
    """(C, 3, hw, hw) fixed per-class spatial frequency patterns."""
    ys, xs = jnp.mgrid[0:hw, 0:hw] / hw
    cls = jnp.arange(num_classes)
    fx = 1.0 + (cls % 5).astype(jnp.float32)
    fy = 1.0 + (cls // 5 % 5).astype(jnp.float32)
    phase = cls.astype(jnp.float32) * 0.7
    pat = jnp.sin(
        2 * jnp.pi * (fx[:, None, None] * xs + fy[:, None, None] * ys)
        + phase[:, None, None]
    )
    chan = jnp.stack([pat, jnp.roll(pat, hw // 4, axis=-1), -pat], axis=1)
    return chan  # (C, 3, hw, hw)


def cifar_like_batch(key, batch: int, hw: int = 32, num_classes: int = 10,
                     noise: float = 0.6) -> dict[str, jax.Array]:
    kl, kn = jax.random.split(key)
    labels = jax.random.randint(kl, (batch,), 0, num_classes)
    pats = _class_pattern(num_classes, hw)
    x = pats[labels] + noise * jax.random.normal(kn, (batch, 3, hw, hw))
    return {"image": x.astype(jnp.float32), "label": labels}


def make_cifar_iterator(batch: int, hw: int = 32, num_classes: int = 10,
                        seed: int = 0):
    @jax.jit
    def next_batch(state: DataState):
        key = jax.random.fold_in(state.key, state.step)
        b = cifar_like_batch(key, batch, hw, num_classes)
        return b, DataState(state.step + 1, state.key)

    return next_batch, DataState.init(seed)


# ---------------------------------------------------------------------------
# LM token streams
# ---------------------------------------------------------------------------
def lm_batch(key, batch: int, seq: int, vocab: int) -> dict[str, jax.Array]:
    """Order-1 Markov stream over a banded transition structure: token t+1 is
    (t * 31 + r) % vocab with r drawn from a small set — learnable by any LM."""
    k1, k2 = jax.random.split(key)
    start = jax.random.randint(k1, (batch, 1), 0, vocab)
    steps = jax.random.randint(k2, (batch, seq - 1), 0, 4)  # small branching

    def scan_fn(tok, r):
        nxt = (tok * 31 + r + 7) % vocab
        return nxt, nxt

    _, rest = jax.lax.scan(scan_fn, start[:, 0], steps.T)
    toks = jnp.concatenate([start, rest.T], axis=1)
    return {"tokens": toks.astype(jnp.int32)}


def make_lm_iterator(batch: int, seq: int, vocab: int, seed: int = 0,
                     extras: tuple[tuple[str, tuple], ...] = ()):
    """``extras``: ((name, shape), ...) additional float inputs (frontend
    embeddings for the vlm/audio stubs)."""

    @jax.jit
    def next_batch(state: DataState):
        key = jax.random.fold_in(state.key, state.step)
        b = lm_batch(key, batch, seq, vocab)
        for i, (name, shape) in enumerate(extras):
            b[name] = jax.random.normal(jax.random.fold_in(key, 100 + i), shape)
        return b, DataState(state.step + 1, state.key)

    return next_batch, DataState.init(seed)
