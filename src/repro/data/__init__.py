from .synthetic import (
    DataState,
    cifar_like_batch,
    lm_batch,
    make_cifar_iterator,
    make_lm_iterator,
)

__all__ = [
    "DataState", "cifar_like_batch", "lm_batch", "make_cifar_iterator",
    "make_lm_iterator",
]
