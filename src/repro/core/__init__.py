"""Core MLS low-bit numerics (the paper's primary contribution)."""
from .formats import EMFormat, FMT_CIFAR, FMT_IMAGENET, GS_FMT_DEFAULT
from .quantize import (
    GroupSpec,
    MLSTensor,
    average_relative_error,
    fake_quant,
    fake_quant_ste,
    mls_quantize,
    pack_elements,
    unpack_elements,
)
from .lowbit import QuantConfig, lowbit_conv, lowbit_matmul

__all__ = [
    "EMFormat", "FMT_CIFAR", "FMT_IMAGENET", "GS_FMT_DEFAULT",
    "GroupSpec", "MLSTensor", "average_relative_error", "fake_quant",
    "fake_quant_ste", "mls_quantize", "pack_elements", "unpack_elements",
    "QuantConfig", "lowbit_conv", "lowbit_matmul",
]
