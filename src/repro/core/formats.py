"""Custom ``<E,M>`` floating-point format math (paper Sec. IV-A, V-C).

A value in the (unsigned) ``<E,M>`` format is

    normal   : (1 + Man/2^M) * 2^e      e in [e_min, -1],  Man in [0, 2^M)
    denormal : (    Man/2^M) * 2^e_min  (gradual underflow, IEEE-754 style)

with ``e_min = 1 - 2^E``.  The exponent is stored as ``-e`` in E bits; the
stored maximum (``-e = 2^E - 1``, i.e. the minimum float magnitude level)
doubles as the denormal level, exactly as described in paper Sec. V-C.
All representable magnitudes lie in ``[0, (2 - 2^-M) * 2^-1] ⊂ [0, 1)``.

The same math implements the group-scale format ``<Eg,Mg>`` (Mg ∈ {0,1}) —
there the fraction is *ceil*-rounded and the value may be exactly 1
(exponent clipped to 0), see :func:`repro.core.quantize.quantize_group_scale`.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "EMFormat",
    "FMT_CIFAR",
    "FMT_IMAGENET",
    "GS_FMT_DEFAULT",
    "accumulation_bits",
    "exponent_fraction",
    "srandom_like",
]


@dataclasses.dataclass(frozen=True)
class EMFormat:
    """Bit layout of a ``<E,M>`` unsigned low-bit float."""

    e: int  # exponent bits
    m: int  # mantissa bits

    def __post_init__(self):
        if self.e < 0 or self.m < 0 or (self.e == 0 and self.m == 0):
            raise ValueError(f"invalid <E,M> format <{self.e},{self.m}>")

    # ---- derived constants -------------------------------------------------
    @property
    def e_min(self) -> int:
        """Most negative normal exponent (== denormal exponent).

        E == 0 is plain fixed point (paper Table II "single number"
        bit-widths): no exponent field, no implicit leading 1 — the grid is
        ``man/2^M`` with step ``2^-M`` over [0, 1)."""
        return 1 - 2**self.e if self.e > 0 else 0

    @property
    def max_value(self) -> float:
        """Largest representable magnitude."""
        if self.e == 0:
            return (2.0**self.m - 1.0) / 2.0**self.m
        return (2.0 - 2.0 ** (-self.m)) * 0.5

    @property
    def min_normal(self) -> float:
        return 2.0**self.e_min

    @property
    def min_subnormal(self) -> float:
        return 2.0 ** (self.e_min - self.m)

    @property
    def element_bits(self) -> int:
        """Storage bits per signed element (sign + exponent + mantissa)."""
        return 1 + self.e + self.m

    @property
    def product_bits(self) -> int:
        """Integer bit-width of a product of two <E,M> values (paper §V-C):
        ``2M + 2^(E+1) - 2`` bits."""
        return 2 * self.m + 2 ** (self.e + 1) - 2

    @property
    def max_fraction(self) -> int:
        """Largest |integer fraction| of a decoded code.

        The quantized-domain GEMM contracts codes as exact integers
        ``F`` with ``|value| = |F| * 2^(e_min - M)`` (``kernels/ref.py``
        ``decode_frac_int``); the largest magnitude is the top normal:
        ``(2^(M+1) - 1) << (2^E - 2)``.  ``max_fraction^2`` spans exactly
        ``product_bits`` bits — the closed form the static interval prover
        (``analysis/intervals.py``) must reproduce from the kernel jaxpr.
        """
        if self.e == 0:
            return 2**self.m - 1
        return (2 ** (self.m + 1) - 1) << (2**self.e - 2)

    def fraction_bound(self) -> tuple[int, int]:
        """``(lo, hi)`` interval of decoded signed integer fractions — the
        operand seed for interval-domain kernel verification."""
        return -self.max_fraction, self.max_fraction

    def grid(self) -> np.ndarray:
        """All representable non-negative values, ascending (for tests)."""
        vals = {0.0}
        for man in range(2**self.m):  # denormals (all values for E == 0)
            vals.add((man / 2**self.m) * 2.0**self.e_min)
        n_exp_levels = 2**self.e - 1 if self.e > 0 else 0
        for k in range(n_exp_levels):  # normals: e = e_min + k .. -1
            e = self.e_min + k
            for man in range(2**self.m):
                vals.add((1 + man / 2**self.m) * 2.0**e)
        return np.array(sorted(vals))

    def __str__(self) -> str:  # matches the paper's ⟨E,M⟩ notation
        return f"<{self.e},{self.m}>"


def accumulation_bits(fmt: EMFormat, k_block: int) -> int:
    """Integer bits spanned by a sum of ``k_block`` products of two ``fmt``
    values: ``product_bits + ceil(log2(k_block))``.  The quantized-domain
    GEMM accumulates in fp32, which is bit-exact only while this stays
    below 24 (see ``kernels/mls_matmul.py``)."""
    if k_block < 1:
        raise ValueError(f"k_block must be >= 1, got {k_block}")
    return fmt.product_bits + math.ceil(math.log2(k_block))


# Paper's headline configurations (Table II).
FMT_CIFAR = EMFormat(e=2, m=1)  # <2,1>: 1-bit mantissa, 2-bit exponent
FMT_IMAGENET = EMFormat(e=2, m=4)  # <2,4>: 4-bit mantissa, 2-bit exponent
GS_FMT_DEFAULT = EMFormat(e=8, m=1)  # group scale <8,1> (paper Table II note)


def exponent_fraction(x: jax.Array):
    """``Exponent``/``Fraction`` of paper Alg. 2: x = frac * 2^e, frac∈[1,2).

    Uses exact bit manipulation of the float32 representation (no log2), so
    results are exact for all finite positive inputs.  x == 0 maps to
    (e=INT32_MIN/2, frac=0) which downstream clipping turns into zero.
    """
    x = x.astype(jnp.float32)
    bits = jax.lax.bitcast_convert_type(x, jnp.int32)
    raw_exp = (bits >> 23) & 0xFF
    man_bits = bits & 0x7FFFFF
    is_zero = x == 0.0
    # fp32 subnormal inputs: treat as zero (they are < 2^-126, far below any
    # <E,M> grid after scaling; scales are maxima so never subnormal).
    is_sub = raw_exp == 0
    e = raw_exp - 127
    frac = jax.lax.bitcast_convert_type(
        jnp.where(is_sub, 0, man_bits) | (127 << 23), jnp.int32
    )
    frac = jax.lax.bitcast_convert_type(frac, jnp.float32)
    e = jnp.where(is_zero | is_sub, jnp.int32(-(2**30)), e)
    frac = jnp.where(is_zero | is_sub, 0.0, frac)
    return e, frac


def srandom_like(key: jax.Array, x: jax.Array) -> jax.Array:
    """U[-1/2, 1/2) tensor for stochastic rounding (paper Eq. 5)."""
    return jax.random.uniform(key, x.shape, jnp.float32, -0.5, 0.5)
