"""Dynamic quantization to the MLS tensor format (paper Alg. 2).

The public entry points are

* :func:`mls_quantize`  — float tensor -> :class:`MLSTensor` (all levels of
  scaling + quantized elements, bit-exact fields).
* :func:`fake_quant`    — float tensor -> float tensor whose values lie
  exactly on the MLS grid (what the paper simulates on GPU).
* :func:`fake_quant_ste` — `fake_quant` with a straight-through estimator,
  used by the low-bit training ops (paper Alg. 1 line 16).

Grouping is expressed by a :class:`GroupSpec`: a per-axis block size.  Block
size 1 makes the axis a pure group axis (one group per index), block size ==
axis length reduces the whole axis into the group.  The paper's "nc" grouping
of a conv operand ``(N, C, H, W)`` is ``GroupSpec((1, 1, H, W))``; a matmul
operand ``(M, K)`` grouped per row and per 128-wide contraction block is
``GroupSpec((1, 128))``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from collections.abc import Sequence

import jax
import jax.numpy as jnp

from .formats import EMFormat, GS_FMT_DEFAULT, exponent_fraction, srandom_like

__all__ = [
    "GroupSpec",
    "MLSTensor",
    "mls_quantize",
    "fake_quant",
    "fake_quant_ste",
    "quantize_group_scale",
    "quantize_elements",
    "average_relative_error",
    "pack_elements",
    "unpack_elements",
]


# --------------------------------------------------------------------------
# Grouping
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class GroupSpec:
    """Per-axis block sizes defining scaling groups.

    ``block[i]`` elements along axis ``i`` share one group (together with the
    blocks of every other axis).  ``None`` means "whole axis in one group".
    """

    block: tuple[int | None, ...]

    def resolve(self, shape: Sequence[int]) -> tuple[int, ...]:
        if len(self.block) != len(shape):
            raise ValueError(f"GroupSpec rank {len(self.block)} != tensor rank {len(shape)}")
        out = []
        for b, d in zip(self.block, shape):
            b = d if b is None else min(b, d)
            if d % b != 0:
                # fall back to one group over the whole axis (coarser scaling,
                # still correct) — keeps odd feature widths working without
                # padding; the Pallas kernels pad instead.
                b = d
            out.append(b)
        return tuple(out)

    def group_shape(self, shape: Sequence[int]) -> tuple[int, ...]:
        return tuple(d // b for d, b in zip(shape, self.resolve(shape)))

    @staticmethod
    def per_tensor(rank: int) -> "GroupSpec":
        return GroupSpec((None,) * rank)

    @staticmethod
    def conv_nc(rank: int = 4) -> "GroupSpec":
        """Paper's best grouping: one group per (dim0, dim1) pair."""
        return GroupSpec((1, 1) + (None,) * (rank - 2))


def _split_axes(x: jax.Array, blocks: tuple[int, ...]):
    """Reshape (d0, d1, ...) -> (g0, b0, g1, b1, ...)."""
    new_shape = []
    for d, b in zip(x.shape, blocks):
        new_shape.extend((d // b, b))
    return x.reshape(new_shape)


def group_reduce_max(x: jax.Array, spec: GroupSpec) -> jax.Array:
    blocks = spec.resolve(x.shape)
    xs = _split_axes(x, blocks)
    axes = tuple(range(1, xs.ndim, 2))
    return jnp.max(xs, axis=axes)


def broadcast_groups(s: jax.Array, spec: GroupSpec, shape: Sequence[int]) -> jax.Array:
    """Broadcast a group-shaped array back to the full tensor shape."""
    blocks = spec.resolve(shape)
    expanded = s.reshape(tuple(v for g in s.shape for v in (g, 1)))
    tiled = jnp.broadcast_to(
        expanded, tuple(v for g, b in zip(s.shape, blocks) for v in (g, b))
    )
    return tiled.reshape(tuple(shape))


# --------------------------------------------------------------------------
# Scale / element quantizers
# --------------------------------------------------------------------------
def quantize_group_scale(s_gf: jax.Array, gs_fmt: EMFormat):
    """Quantize group/tensor scale ratios in (0, 1] (paper Alg. 2 l.4-8).

    Fractions are *ceil*-rounded so the quantized scale is >= the true ratio,
    guaranteeing normalized elements stay <= 1.  Returns ``(s_g, exp_g,
    man_g)`` where ``s_g = (1 + man_g/2^Mg) * 2^-exp_g`` exactly.
    """
    # fp32 cannot represent 2^e below ~2^-126; group ratios that small mean
    # "all-zero group", so clamping the exponent there is exact in effect.
    e_min = max(gs_fmt.e_min, -120)
    e, frac = exponent_fraction(s_gf)
    # values below the smallest normal scale are ceil'd up to it
    too_small = e < e_min
    e = jnp.clip(e, e_min, 0)
    frac = jnp.where(too_small, 1.0, frac)
    man = jnp.ceil((frac - 1.0) * 2.0**gs_fmt.m).astype(jnp.int32)
    # fraction overflow: man == 2^Mg means frac_q == 2 -> bump exponent
    overflow = man >= 2**gs_fmt.m
    man = jnp.where(overflow, 0, man)
    e = jnp.clip(jnp.where(overflow, e + 1, e), e_min, 0)
    s_g = (1.0 + man.astype(jnp.float32) * 2.0**-gs_fmt.m) * jnp.exp2(
        e.astype(jnp.float32)
    )
    return s_g, (-e).astype(jnp.int32), man


def quantize_elements(
    x_f: jax.Array,
    fmt: EMFormat,
    r: jax.Array | None = None,
):
    """Quantize normalized magnitudes in [0, 1] to the <E,M> grid.

    Implements paper Alg. 2 lines 9-16: per-element exponent extraction,
    mantissa stochastic rounding (``r`` is the U[-1/2,1/2) tensor; ``None``
    means round-to-nearest), IEEE-754 gradual underflow at ``e_min`` and
    saturation at the top of the grid.  Returns ``(xbar, exp_stored, man)``
    with ``xbar`` the dequantized magnitude (exactly on the grid).
    """
    x_f = x_f.astype(jnp.float32)
    if fmt.e == 0:
        # plain fixed point: uniform grid man/2^M over [0, 1)
        step = jnp.float32(2.0**-fmt.m)
        scaled = x_f / step
        q = jnp.floor(scaled + (r if r is not None else 0.0) + 0.5)
        q = jnp.clip(q, 0.0, 2.0**fmt.m - 1.0)
        xbar = q * step
        e_eff = jnp.zeros_like(x_f, jnp.int32)
    else:
        e, _ = exponent_fraction(x_f)
        e_eff = jnp.clip(e, fmt.e_min, -1)
        # step = 2^(e_eff - M): grid spacing at this exponent level (covers
        # denormals too: at e_min the denormal step equals the normal step).
        step = jnp.exp2((e_eff - fmt.m).astype(jnp.float32))
        scaled = x_f / step
        if r is not None:
            q = jnp.floor(scaled + r + 0.5)
        else:
            q = jnp.floor(scaled + 0.5)
        # top-of-grid saturation: at e_eff == -1 the next exponent (0) does
        # not exist, clip to (2 - 2^-M) * 2^-1.  At lower exponents
        # q == 2^(M+1) legitimately rounds up into the next exponent level.
        qmax = jnp.where(e_eff == -1, 2.0 ** (fmt.m + 1) - 1.0,
                         2.0 ** (fmt.m + 1))
        q = jnp.clip(q, 0.0, qmax)
        xbar = q * step

    # exact storage fields from the on-grid value
    e2, frac2 = exponent_fraction(xbar)
    is_normal = e2 >= fmt.e_min
    man = jnp.where(
        is_normal,
        jnp.round((frac2 - 1.0) * 2.0**fmt.m),
        jnp.round(xbar * 2.0 ** (fmt.m - fmt.e_min)),
    ).astype(jnp.int32)
    # IEEE-style storage: stored 0 flags denormal (effective exponent e_min),
    # stored s in [1, 2^E - 1] is a normal with e = -s ("the minimum value of
    # the exponent is used to represent underflow", paper Sec. V-C).
    exp_stored = jnp.where(is_normal, -e2, 0).astype(jnp.int32)
    return xbar, exp_stored, man


# --------------------------------------------------------------------------
# MLS tensor container
# --------------------------------------------------------------------------
@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class MLSTensor:
    """A tensor in the multi-level-scaling format (paper Eq. 2).

    ``x = sign * s_t * broadcast(s_g) * xbar`` where ``xbar`` carries the
    ``<Ex,Mx>`` element values (stored both dequantized and as exact
    exponent/mantissa integer fields for the bit-exact kernels).
    """

    sign: jax.Array  # int8, +-1 (0 for zero elements)
    s_t: jax.Array  # f32 scalar tensor-wise scale
    s_g: jax.Array  # f32, group shape (dequantized group scales)
    exp_g: jax.Array  # int32, group shape (stored exponent, >= 0)
    man_g: jax.Array  # int32, group shape
    xbar: jax.Array  # f32, full shape, on-grid magnitudes in [0, 1)
    exp_x: jax.Array  # int32, full shape (stored exponent, >= 0)
    man_x: jax.Array  # int32, full shape
    fmt: EMFormat = dataclasses.field(metadata={"static": True})
    gs_fmt: EMFormat = dataclasses.field(metadata={"static": True})
    spec: GroupSpec = dataclasses.field(metadata={"static": True})

    def tree_flatten(self):
        children = (
            self.sign, self.s_t, self.s_g, self.exp_g, self.man_g,
            self.xbar, self.exp_x, self.man_x,
        )
        return children, (self.fmt, self.gs_fmt, self.spec)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    @property
    def shape(self):
        return self.xbar.shape

    def dequant(self) -> jax.Array:
        scale = self.s_t * broadcast_groups(self.s_g, self.spec, self.shape)
        return self.sign.astype(jnp.float32) * scale * self.xbar

    def unit_value(self) -> jax.Array:
        """Dequantized value with the tensor scale ``s_t`` factored out.

        ``sign * s_g * xbar`` has at most ``(Mg+1)+(Mx+1)`` mantissa bits, so
        for the paper's formats it is *exactly* representable in bf16 — this
        is what the low-bit GEMMs consume on the MXU (paper Sec. V-B: the
        tensor-wise scale is applied once to the GEMM output, not per MAC).
        """
        scale = broadcast_groups(self.s_g, self.spec, self.shape)
        return self.sign.astype(jnp.float32) * scale * self.xbar

    def frac_int(self) -> jax.Array:
        """Integer fraction F such that ``xbar = F * 2^(e_min - M)``.

        ``F = (2^M + man) << (2^E - 1 - exp_stored)`` for normals (stored
        exponent in [1, 2^E-1]), ``F = man`` for denormals (stored 0).  This
        is the integer the paper's adder tree multiplies and accumulates
        (Eq. 7); its width is ``M + 2^E - 1`` bits.
        """
        fmt = self.fmt
        top = 2**fmt.e - 1
        is_denorm = self.exp_x == 0
        base = jnp.where(is_denorm, self.man_x, 2**fmt.m + self.man_x)
        shift = jnp.where(is_denorm, 0, top - self.exp_x)
        return base << shift


def mls_quantize(
    x: jax.Array,
    fmt: EMFormat,
    spec: GroupSpec | None = None,
    gs_fmt: EMFormat = GS_FMT_DEFAULT,
    key: jax.Array | None = None,
) -> MLSTensor:
    """Full dynamic quantization, paper Alg. 2."""
    x = x.astype(jnp.float32)
    if spec is None:
        spec = GroupSpec.per_tensor(x.ndim)
    sign = jnp.sign(x).astype(jnp.int8)
    absx = jnp.abs(x)
    s_r = group_reduce_max(absx, spec)  # group maxima
    s_t = jnp.max(s_r)  # tensor scale
    s_t_safe = jnp.where(s_t > 0, s_t, 1.0)
    s_gf = s_r / s_t_safe
    s_g, exp_g, man_g = quantize_group_scale(s_gf, gs_fmt)
    denom = s_t_safe * broadcast_groups(s_g, spec, x.shape)
    x_f = jnp.where(denom > 0, absx / jnp.where(denom > 0, denom, 1.0), 0.0)
    r = srandom_like(key, x) if key is not None else None
    xbar, exp_x, man_x = quantize_elements(x_f, fmt, r)
    return MLSTensor(
        sign=sign, s_t=s_t_safe, s_g=s_g, exp_g=exp_g, man_g=man_g,
        xbar=xbar, exp_x=exp_x, man_x=man_x, fmt=fmt, gs_fmt=gs_fmt, spec=spec,
    )


def fake_quant(
    x: jax.Array,
    fmt: EMFormat,
    spec: GroupSpec | None = None,
    gs_fmt: EMFormat = GS_FMT_DEFAULT,
    key: jax.Array | None = None,
) -> jax.Array:
    """Quantize-dequantize: returns an fp32 tensor exactly on the MLS grid."""
    return mls_quantize(x, fmt, spec, gs_fmt, key).dequant()


@partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def fake_quant_ste(x, fmt, spec, gs_fmt, key=None):
    return fake_quant(x, fmt, spec, gs_fmt, key)


def _fq_fwd(x, fmt, spec, gs_fmt, key=None):
    return fake_quant(x, fmt, spec, gs_fmt, key), None


def _fq_bwd(fmt, spec, gs_fmt, res, g):
    return (g, None)


fake_quant_ste.defvjp(_fq_fwd, _fq_bwd)


# --------------------------------------------------------------------------
# Packed int8 codec (for quantized storage / collective compression)
# --------------------------------------------------------------------------
def pack_elements(t: MLSTensor) -> jax.Array:
    """Pack sign/exp/man into uint8 codes: [sign | exp | man] (<= 8 bits)."""
    fmt = t.fmt
    if fmt.element_bits > 8:
        raise ValueError(f"{fmt} does not fit in 8 bits")
    sign_bit = (t.sign.astype(jnp.int32) < 0).astype(jnp.int32)
    code = (sign_bit << (fmt.e + fmt.m)) | (t.exp_x << fmt.m) | t.man_x
    return code.astype(jnp.uint8)


def unpack_elements(code: jax.Array, fmt: EMFormat):
    """Inverse of :func:`pack_elements` -> (sign, xbar) dequantized fields."""
    code = code.astype(jnp.int32)
    man = code & (2**fmt.m - 1)
    exp = (code >> fmt.m) & (2**fmt.e - 1)
    sign_bit = code >> (fmt.e + fmt.m)
    top = 2**fmt.e - 1
    is_denorm = exp == 0
    frac = jnp.where(is_denorm, 0.0, 1.0) + man.astype(jnp.float32) * 2.0**-fmt.m
    mag = frac * jnp.exp2(-jnp.where(is_denorm, top, exp).astype(jnp.float32))
    sign = 1.0 - 2.0 * sign_bit.astype(jnp.float32)
    # zero has man==0, exp==0 (denormal) -> mag 0; sign bit irrelevant
    return sign, mag


# --------------------------------------------------------------------------
# Metrics
# --------------------------------------------------------------------------
def average_relative_error(x: jax.Array, q: jax.Array) -> jax.Array:
    """ARE used in the paper's Fig. 7 / Table IV analysis:
    mean(|x - q|) / mean(|x|)."""
    return jnp.mean(jnp.abs(x - q)) / jnp.maximum(jnp.mean(jnp.abs(x)), 1e-30)
