"""Low-bit training ops (paper Alg. 1 / Sec. V-B).

``lowbit_matmul`` / ``lowbit_conv`` quantize **both operands** to the MLS
format on the forward pass and quantize the **back-propagated error** before
the two backward GEMMs/convs, exactly as paper Alg. 1:

    forward : Z  = Conv(qW, qA)                        (l.4)
    backward: G  = Conv(qE, qA)      -> weight grad    (l.13)
              dA = Conv(qE, qW), STE -> input grad     (l.15-16)

Straight-through estimation means the gradient w.r.t. the *float* operands is
the gradient w.r.t. their quantized versions.  Convolution/matmul outputs are
full precision (the paper keeps BN & friends in fp32).

Quantization is stochastic when a PRNG key is supplied (paper Eq. 5) and
round-to-nearest when ``key`` is ``None``.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from .formats import EMFormat, FMT_IMAGENET, GS_FMT_DEFAULT, accumulation_bits
from .quantize import GroupSpec, fake_quant, mls_quantize

__all__ = ["QuantConfig", "lowbit_matmul", "lowbit_conv", "quantize_operand"]


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """How a layer quantizes its three conv/matmul operands."""

    fmt: EMFormat = FMT_IMAGENET  # <Ex,Mx> for W/A/E (paper uses one format)
    gs_fmt: EMFormat = GS_FMT_DEFAULT  # <Eg,Mg> group-scale format
    grouping: str = "nc"  # "nc" | "c" | "n" | "none"  (paper Table IV)
    k_block: int = 128  # contraction block for matmul grouping (TPU tile)
    stochastic: bool = True  # stochastic rounding (False -> nearest)
    compute_dtype: jnp.dtype = jnp.float32  # dot dtype (bf16 on TPU is exact
    # for MLS values when M <= 7 since products accumulate in fp32 on MXU)
    enabled: bool = True
    # Route the quantized WEIGHT through its packed uint8 representation with
    # an FSDP sharding constraint on the codes: under FSDP, XLA then
    # all-gathers 1-byte codes (+ tiny scales) instead of fp32/bf16 weights —
    # the paper's wire format as a distributed-training compressor.
    # Mathematically a no-op (pack/unpack is exact).
    packed_wire: bool = False
    # Which weight dim is FSDP-sharded (0 for in-projections, 1 for
    # out-projections); None disables the wire pinning.  Set per-callsite by
    # the layer code (nn.linear(..., wire=...)).
    wire_fsdp_dim: int | None = None
    # Contraction axes of the GEMM weights are FSDP-sharded this many ways in
    # the production mesh; scaling-group reshapes must align to the shard
    # boundaries or XLA gathers the *unquantized* weight to form groups.
    # 1 = no alignment (single-host tests); production configs set 16.
    shard_ways: int = 1
    # Which arithmetic executes the three training GEMMs/convs:
    #   "fake_quant": quantize-dequantize + XLA conv/dot (GPU-style simulation)
    #   "pallas":     quantized-domain Pallas kernels over the im2col/implicit
    #                 GEMM lowering (kernels.lowbit_conv) — the paper's real
    #                 low-bit arithmetic.  `grouping` selects the kernel's
    #                 group-scale layout (the matmul analogue of Table IV),
    #                 with the contraction axis playing the input channel.
    backend: str = "fake_quant"
    # Pallas execution mode: None = defer to the process-wide switch
    # (explicit > REPRO_PALLAS_INTERPRET env > Mosaic on TPU / interpreter
    # elsewhere); set explicitly to force either.
    pallas_interpret: bool | None = None
    # Pallas GEMM output tiles.  None = resolve per call-site shape through
    # the autotuner cache (kernels.autotune: explicit override > cache hit >
    # proven-legal default); set to pin a tiling explicitly.  For the
    # implicit conv, block_m is the M-tile in GEMM rows (must be bh*OW with
    # bh | OH) and block_n the output-channel tile.
    block_m: int | None = None
    block_n: int | None = None
    # Forward-conv lowering on the pallas backend: "im2col" materializes the
    # patch matrix, "implicit" runs the fused implicit-GEMM kernel
    # (kernels.implicit_conv; requires k_block = cb*kh*kw with cb | C), and
    # "auto" resolves REPRO_CONV_IMPL env > tuned cache > implicit-when-
    # legal.  Never changes quantization semantics: incompatible k_blocks
    # stay on im2col, explicit "implicit" on one raises.
    conv_impl: str = "auto"

    def __post_init__(self):
        if self.backend not in ("fake_quant", "pallas"):
            raise ValueError(
                f"QuantConfig.backend must be 'fake_quant' or 'pallas', "
                f"got {self.backend!r}"
            )
        if self.grouping not in ("nc", "c", "n", "none"):
            raise ValueError(
                f"QuantConfig.grouping must be one of 'nc'/'c'/'n'/'none', "
                f"got {self.grouping!r}"
            )
        if self.conv_impl not in ("auto", "im2col", "implicit"):
            raise ValueError(
                f"QuantConfig.conv_impl must be 'auto', 'im2col' or "
                f"'implicit', got {self.conv_impl!r}"
            )
        # Accumulator-exactness invariant (paper Sec. V-B / mls_matmul.py):
        # a scaling group sums k_block products of product_bits-wide integers
        # in fp32, which is bit-exact only below 2^24.  Refuse configs that
        # would silently produce rounded sums.
        acc = accumulation_bits(self.fmt, self.k_block)
        if acc >= 24:
            raise ValueError(
                f"QuantConfig: accumulating k_block={self.k_block} products "
                f"of {self.fmt} values spans {acc} integer bits "
                f"(product_bits={self.fmt.product_bits} + "
                f"ceil(log2(k_block))) >= 24, so fp32 accumulation is no "
                f"longer exact integer arithmetic. Reduce k_block or use a "
                f"narrower <E,M> format."
            )

    def _aligned_kb(self, k: int) -> int:
        if self.shard_ways > 1:
            for kb in (self.k_block, 64, 32, 16):
                if k % kb == 0 and (k // kb) % self.shard_ways == 0:
                    return kb
        return min(self.k_block, k)

    def matmul_specs(self, x_shape, w_shape) -> tuple[GroupSpec, GroupSpec]:
        """Group specs for ``x @ w`` with x: (..., K), w: (K, N).

        The matmul analogue of the paper's conv grouping: the contraction
        axis plays the role of the input channel.  "nc" gives one scale per
        (row, k-block) of x and per (k-block, column-block) of w.
        """
        kb = self._aligned_kb(x_shape[-1])
        if self.grouping == "none":
            return (GroupSpec.per_tensor(len(x_shape)), GroupSpec.per_tensor(2))
        if self.grouping == "c":  # contraction blocks only
            return (
                GroupSpec((None,) * (len(x_shape) - 1) + (kb,)),
                GroupSpec((kb, None)),
            )
        if self.grouping == "n":  # row/column only
            return (
                GroupSpec((1,) * (len(x_shape) - 1) + (None,)),
                GroupSpec((None, kb)),
            )
        # "nc" (paper's best): activation per (row, k-block); weight per
        # (k-block, output-channel) — the (Co, Ci) grouping of the paper.
        return (
            GroupSpec((1,) * (len(x_shape) - 1) + (kb,)),
            GroupSpec((kb, 1)),
        )

    def conv_specs(self) -> tuple[GroupSpec, GroupSpec]:
        """Group specs for NCHW activations / OIHW weights (paper Sec. IV-B)."""
        if self.grouping == "none":
            return GroupSpec.per_tensor(4), GroupSpec.per_tensor(4)
        if self.grouping == "c":
            return GroupSpec((None, 1, None, None)), GroupSpec((None, 1, None, None))
        if self.grouping == "n":
            return GroupSpec((1, None, None, None)), GroupSpec((1, None, None, None))
        return GroupSpec.conv_nc(), GroupSpec.conv_nc()


def _maybe_key(key: jax.Array | None, cfg: QuantConfig, idx: int):
    if key is None or not cfg.stochastic:
        return None
    return jax.random.fold_in(key, idx)


def quantize_operand(x, cfg: QuantConfig, spec: GroupSpec, key, idx: int,
                     wire: bool = False):
    """Quantize -> (unit-scaled values in compute dtype, fp32 tensor scale).

    The tensor-wise scale is factored out of the GEMM (paper Sec. V-B), so
    the unit values have <= (Mg+1)+(Mx+1) mantissa bits and the bf16 cast is
    exact for the paper's formats.

    With ``wire=True`` and ``cfg.packed_wire`` the quantized weight is routed
    through its packed uint8 codes with an FSDP sharding constraint, so the
    FSDP all-gather moves 1 B/element instead of 4 B (exact round trip).
    """
    if not cfg.enabled:
        return x.astype(cfg.compute_dtype), jnp.float32(1.0)
    t = mls_quantize(x, cfg.fmt, spec, cfg.gs_fmt, _maybe_key(key, cfg, idx))
    pin = wire and cfg.wire_fsdp_dim is not None and x.ndim == 2
    if pin and cfg.packed_wire and t.fmt.element_bits <= 8:
        from repro.parallel.sharding import wire_pin

        from .quantize import broadcast_groups, pack_elements, unpack_elements

        codes = wire_pin(pack_elements(t), cfg.wire_fsdp_dim)  # u8 gather
        sign, mag = unpack_elements(codes, cfg.fmt)
        # gather the group scales in COMPACT form (1/k_block of the element
        # count) and broadcast locally — broadcasting first would gather a
        # full-resolution f32 tensor and defeat the 1-byte wire format.
        sg_dim = min(cfg.wire_fsdp_dim, t.s_g.ndim - 1)
        sgc = wire_pin(t.s_g, sg_dim)
        sg = broadcast_groups(sgc, t.spec, x.shape)
        unit = (sign * mag * sg).astype(cfg.compute_dtype)
        return unit, t.s_t
    unit = t.unit_value().astype(cfg.compute_dtype)
    if pin:
        from repro.parallel.sharding import wire_pin

        unit = wire_pin(unit, cfg.wire_fsdp_dim)  # bf16 gather
    return unit, t.s_t


# ---------------------------------------------------------------------------
# Low-bit matmul
# ---------------------------------------------------------------------------
@partial(jax.custom_vjp, nondiff_argnums=(3,))
def lowbit_matmul(x, w, key, cfg: QuantConfig):
    """``x @ w`` with MLS-quantized operands; x: (..., K), w: (K, N)."""
    y, _ = _lm_fwd(x, w, key, cfg)
    return y


def _lm_fwd(x, w, key, cfg: QuantConfig):
    sx, sw = cfg.matmul_specs(x.shape, w.shape)
    qx, stx = quantize_operand(x, cfg, sx, key, 0)
    qw, stw = quantize_operand(w, cfg, sw, key, 1, wire=True)
    y = jax.lax.dot_general(
        qx, qw,
        (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * (stx * stw)
    protos = (jnp.zeros((), x.dtype), jnp.zeros((), w.dtype))
    return y, (qx, stx, qw, stw, key, protos)


def _lm_bwd(cfg: QuantConfig, res, g):
    qx, stx, qw, stw, key, (xp, wp) = res
    # quantize the error once (paper Alg. 1 l.12), reuse for both grads
    ge = g.astype(jnp.float32)
    if cfg.enabled:
        se = GroupSpec(
            (1,) * (ge.ndim - 1) + (min(cfg.k_block, ge.shape[-1]),)
            if cfg.grouping in ("nc", "c")
            else (None,) * ge.ndim
        )
        te = mls_quantize(ge, cfg.fmt, se, cfg.gs_fmt, _maybe_key(key, cfg, 2))
        ge, ste = te.unit_value().astype(cfg.compute_dtype), te.s_t
    else:
        ge, ste = ge.astype(cfg.compute_dtype), jnp.float32(1.0)
    # dX = qE @ qW^T   (paper l.15: LowbitConv(qE, qW))
    dx = jax.lax.dot_general(
        ge, qw, (((ge.ndim - 1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * (ste * stw)
    # dW = qX^T @ qE   (paper l.13: G = LowbitConv(qE, qA))
    batch_axes = tuple(range(ge.ndim - 1))
    dw = jax.lax.dot_general(
        qx, ge, ((batch_axes, batch_axes), ((), ())),
        preferred_element_type=jnp.float32,
    ) * (ste * stx)
    return dx.astype(xp.dtype), dw.astype(wp.dtype), None


lowbit_matmul.defvjp(_lm_fwd, _lm_bwd)


# ---------------------------------------------------------------------------
# Low-bit convolution (NCHW / OIHW)
# ---------------------------------------------------------------------------
def _conv(x, w, stride, padding):
    return jax.lax.conv_general_dilated(
        x, w, window_strides=stride, padding=padding,
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
        preferred_element_type=jnp.float32,
    )


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def lowbit_conv(x, w, key, stride, padding, cfg: QuantConfig):
    """NCHW conv with MLS-quantized W/A/E (paper Alg. 1)."""
    y, _ = _lc_fwd(x, w, key, stride, padding, cfg)
    return y


def _lc_fwd(x, w, key, stride, padding, cfg: QuantConfig):
    sa, sw = cfg.conv_specs()
    qx, stx = quantize_operand(x, cfg, sa, key, 0)
    qw, stw = quantize_operand(w, cfg, sw, key, 1)
    y = _conv(qx, qw, stride, padding) * (stx * stw)
    protos = (jnp.zeros((), x.dtype), jnp.zeros((), w.dtype))
    return y, (qx, stx, qw, stw, key, protos)


def _lc_bwd(stride, padding, cfg: QuantConfig, res, g):
    qx, stx, qw, stw, key, (xp, wp) = res
    ge = g.astype(jnp.float32)
    if cfg.enabled:
        se, _ = cfg.conv_specs()  # error grouped by (n, co) like activations
        te = mls_quantize(ge, cfg.fmt, se, cfg.gs_fmt, _maybe_key(key, cfg, 2))
        ge, ste = te.unit_value(), te.s_t
    else:
        ste = jnp.float32(1.0)
    # transpose convs via the vjp of the clean conv evaluated at (qx, qw)
    _, vjp = jax.vjp(lambda a, b: _conv(a, b, stride, padding), qx, qw)
    dx, dw = vjp(ge.astype(cfg.compute_dtype).astype(jnp.float32))
    dx = dx.astype(jnp.float32) * (ste * stw)
    dw = dw.astype(jnp.float32) * (ste * stx)
    return dx.astype(xp.dtype), dw.astype(wp.dtype), None


lowbit_conv.defvjp(_lc_fwd, _lc_bwd)
