"""The paper's analytical energy model (Tables V & VI, Eq. 12).

Per-op energies are the paper's Design-Compiler numbers (TSMC 65 nm, 1 GHz;
mW at 1 GHz == pJ/op).  These do NOT transfer to TPU — they are kept verbatim
as the *paper-reproduction* artifact (DESIGN.md §3/§8); TPU performance is
reported through the roofline pipeline instead.

Frameworks:
* ``fp32`` — full-precision training
* ``fp8``  — 8-bit floating-point MULs, fp32 accumulation (HFP8 [14])
* ``int8`` — 8-bit integer (FullINT [12])
* ``mls``  — this paper: <2,4>(+sign) 7-bit MUL, integer local accumulation,
  shift-add group-wise scaling, fp32 adder-tree level.
"""
from __future__ import annotations


from repro.models.cnn import CNNConfig, count_ops

# Table V (pJ/op at 65 nm, 1 GHz).
MAC_ENERGY_PJ = {
    "fp32": {"mul": 2.311, "acc": 0.512},
    "fp8": {"mul": 0.105, "acc": 0.512},
    "int8": {"mul": 0.155, "acc": 0.065},
    "mls": {"mul": 0.124, "acc": 0.065},
}
FLOAT_MUL = 2.311
FLOAT_ADD = 0.512


def conv_energy_ratio(k: int = 3) -> float:
    """Eq. 12: energy ratio of a KxK conv MAC group, fp32 vs MLS (~11.5).

    Per input-channel group: K*K MULs + K*K local accumulations + one
    adder-tree addition; MLS adds one group-wise scale op (costed like a
    local accumulation, Eq. 8)."""
    n = k * k
    full = FLOAT_MUL * n + FLOAT_ADD * n + FLOAT_ADD * 1
    ours = (
        MAC_ENERGY_PJ["mls"]["mul"] * n
        + MAC_ENERGY_PJ["mls"]["acc"] * (n + 1)  # local acc + group scale
        + FLOAT_ADD * 1  # adder tree stays fp
    )
    return full / ours


def _op_totals(cfg: CNNConfig) -> dict[str, float]:
    ops = count_ops(cfg, batch=1)
    conv_macs = sum(d["c_in"] * d["c_out"] * d["k"] ** 2 * d["h"] * d["w"] * d["n"]
                    for kd, d in ops if kd == "conv")
    conv_tree = sum(d["c_in"] * d["c_out"] * d["h"] * d["w"] * d["n"]
                    for kd, d in ops if kd == "conv")
    fc_macs = sum(d["d_in"] * d["d_out"] * d["rows"] for kd, d in ops if kd == "fc")
    bn_elems = sum(d["numel"] for kd, d in ops if kd == "bn")
    ew_elems = sum(d["numel"] for kd, d in ops if kd == "ew_add")
    act_elems = sum(d["c_out"] * d["h"] * d["w"] * d["n"]
                    for kd, d in ops if kd == "conv")
    w_elems = sum(d["c_in"] * d["c_out"] * d["k"] ** 2 for kd, d in ops if kd == "conv")
    return {
        "conv_macs_fwd": conv_macs,
        "conv_tree_fwd": conv_tree,
        "fc_macs_fwd": fc_macs,
        "bn_elems_fwd": bn_elems,
        "ew_elems_fwd": ew_elems,
        "act_elems": act_elems,
        "w_elems": w_elems,
    }


def network_energy(cfg: CNNConfig, framework: str = "mls") -> dict[str, float]:
    """Per-image training-step energy (uJ), paper Table VI methodology.

    Training = 3 conv passes (fwd + error-bwd + weight-grad, Table I);
    BN fwd 5 ops + bwd 12 ops per element (paper Eq. 13/14: 9 mul + 10 add);
    SGD update: 1 mul + 1 add per weight (+momentum: 2/2 — paper counts a
    plain update, we follow the paper); DQ: 4 mul + 2 add per quantized
    element (W once, A once, E once per step).
    """
    t = _op_totals(cfg)
    e = MAC_ENERGY_PJ[framework]
    train_macs = 3 * t["conv_macs_fwd"]
    train_tree = 3 * t["conv_tree_fwd"]
    rows: dict[str, float] = {}
    if framework == "fp32":
        rows["conv_mul"] = train_macs * FLOAT_MUL
        rows["conv_add"] = train_macs * FLOAT_ADD
    else:
        rows["conv_mul"] = train_macs * e["mul"]
        # local accumulation + group-wise scaling at the acc cost
        rows["conv_acc"] = train_macs * e["acc"]
        if framework == "mls":
            rows["group_scale"] = train_tree * e["acc"]
        # adder-tree level stays floating point (fp8/mls); int8 keeps int
        tree_cost = FLOAT_ADD if framework in ("fp8", "mls") else e["acc"]
        rows["conv_tree"] = train_tree * tree_cost
    # BN: 9 mul + 10 add per element over fwd+bwd (paper Sec. VI-E)
    rows["bn"] = t["bn_elems_fwd"] * (9 * FLOAT_MUL + 10 * FLOAT_ADD) / 2
    # FC fwd+bwd (3 passes), full precision in every framework
    rows["fc"] = 3 * t["fc_macs_fwd"] * (FLOAT_MUL + FLOAT_ADD)
    # SGD update (full precision everywhere)
    rows["sgd"] = t["w_elems"] * (2 * FLOAT_MUL + 2 * FLOAT_ADD)
    # element-wise residual adds (+ scale-merge muls for MLS, Table VI)
    rows["ew_add"] = t["ew_elems_fwd"] * 2 * FLOAT_ADD
    if framework == "mls":
        rows["ew_add"] += t["ew_elems_fwd"] * FLOAT_MUL
        dq_elems = t["w_elems"] + 2 * t["act_elems"]
        rows["dq"] = dq_elems * (4 * FLOAT_MUL + 2 * FLOAT_ADD)
    total_pj = sum(rows.values())
    rows = {k: v * 1e-6 for k, v in rows.items()}  # pJ -> uJ
    rows["total_uj"] = total_pj * 1e-6
    return rows


def efficiency_ratios(cfg: CNNConfig) -> dict[str, float]:
    ours = network_energy(cfg, "mls")["total_uj"]
    return {
        "vs_fp32": network_energy(cfg, "fp32")["total_uj"] / ours,
        "vs_fp8": network_energy(cfg, "fp8")["total_uj"] / ours,
        "vs_int8": network_energy(cfg, "int8")["total_uj"] / ours,
    }
