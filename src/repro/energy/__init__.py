from .model import (
    MAC_ENERGY_PJ,
    conv_energy_ratio,
    efficiency_ratios,
    network_energy,
)

__all__ = ["MAC_ENERGY_PJ", "conv_energy_ratio", "efficiency_ratios",
           "network_energy"]
