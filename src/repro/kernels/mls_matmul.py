"""Pallas TPU kernel: low-bit tensor GEMM in the MLS quantized domain.

Implements the paper's low-bit convolution arithmetic (Sec. V-B, Eq. 6-8)
adapted to TPU as a tiled matmul:

* **Intra-group MACs** (Eq. 7): packed uint8 codes are decoded to signed
  integer fractions ``F`` (``|F| < 2^(M + 2^E - 1)``) and contracted over one
  ``k_block``-wide scaling group with an MXU ``dot``.  Products are at most
  ``2M + 2^(E+1) - 2`` bits (14 for the paper's ImageNet format ⟨2,4⟩), so
  fp32 accumulation over a 128-deep group is **bit-exact integer
  arithmetic** — the TPU-native analogue of the paper's int accumulator
  (fp32 is exact below 2^24; 14-bit products x 128 depth = 21 bits).
* **Inter-group combine** (Eq. 8): the partial sum of each group is scaled
  by ``S_p = s_g^x * s_g^w`` — a ⟨Eg,2⟩ value, i.e. a sum of <= 3 shifted
  copies in the paper's adder tree; here an exact fp32 multiply — and
  accumulated across groups in the fp32 output tile (the "TreeAdd" level).
* The tensor scales ``s_t^x * s_t^w`` multiply the output tile once
  (paper Sec. V-B: tensor-wise scale folded out of the MAC array).

Grid: ``(M/bm, N/bn, K/bk)`` with the contraction innermost; ``bk`` equals
the scaling-group width so group boundaries coincide with VMEM tiles.

**Grouping is a first-class kernel parameter** (paper Table IV): the
group-scale operands arrive in the compact layout of the grouping and the
BlockSpecs are reshaped per layout — ``"nc"`` (per row x k-block / k-block
x column), ``"c"`` (per k-block, shared across rows/columns), ``"n"`` (per
row / per column, constant along K) or ``"none"`` (all-ones, the tensor
scale carries everything).  See :func:`sg_shapes` for the exact layouts.
The kernel body is layout-generic: the scale blocks broadcast against the
(bm, bn) partial-product tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.formats import EMFormat
from .runtime import resolve_interpret

GROUPINGS = ("nc", "c", "n", "none")


def sg_shapes(
    grouping: str, M: int, N: int, n_kb: int
) -> tuple[tuple[int, int], tuple[int, int]]:
    """Compact group-scale layouts ``(x_sg, w_sg)`` for an (M, K, N) GEMM.

    ``"nc"``: x (M, K/kb), w (K/kb, N) — one scale per (row, k-block) /
    (k-block, column); ``"c"``: (1, K/kb) / (K/kb, 1); ``"n"``: (M, 1) /
    (1, N); ``"none"``: (1, 1) / (1, 1).
    """
    if grouping == "nc":
        return (M, n_kb), (n_kb, N)
    if grouping == "c":
        return (1, n_kb), (n_kb, 1)
    if grouping == "n":
        return (M, 1), (1, N)
    if grouping == "none":
        return (1, 1), (1, 1)
    raise ValueError(f"unknown grouping {grouping!r}; expected {GROUPINGS}")


def _sg_specs(grouping: str, block_m: int, block_n: int):
    """BlockSpecs delivering the right scale slice per grid point."""
    if grouping == "nc":
        return (
            pl.BlockSpec((block_m, 1), lambda i, j, k: (i, k)),
            pl.BlockSpec((1, block_n), lambda i, j, k: (k, j)),
        )
    if grouping == "c":
        return (
            pl.BlockSpec((1, 1), lambda i, j, k: (0, k)),
            pl.BlockSpec((1, 1), lambda i, j, k: (k, 0)),
        )
    if grouping == "n":
        return (
            pl.BlockSpec((block_m, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((1, block_n), lambda i, j, k: (0, j)),
        )
    return (  # "none"
        pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
        pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
    )


def _decode_frac(codes, fmt: EMFormat):
    """uint8 codes -> signed integer fractions as exact fp32 values."""
    c = codes.astype(jnp.int32)
    man = c & (2**fmt.m - 1)
    exp = (c >> fmt.m) & (2**fmt.e - 1)
    sign_bit = c >> (fmt.e + fmt.m)
    top = 2**fmt.e - 1
    is_denorm = exp == 0
    base = jnp.where(is_denorm, man, 2**fmt.m + man)
    shift = jnp.where(is_denorm, 0, top - exp)
    f = (base << shift).astype(jnp.float32)
    return jnp.where(sign_bit == 1, -f, f)


def _kernel(
    xc_ref, xsg_ref, wc_ref, wsg_ref, st_ref, out_ref, acc_ref, *, fmt, n_k
):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    fx = _decode_frac(xc_ref[...], fmt)  # (bm, bk) exact small ints
    fw = _decode_frac(wc_ref[...], fmt)  # (bk, bn)
    # Intra-group integer MACs on the MXU (exact in fp32, see module doc).
    p = jnp.dot(fx, fw, preferred_element_type=jnp.float32)  # (bm, bn)
    # Inter-group scale S_p = s_g^x ⊗ s_g^w (shift-add in HW, exact here).
    # The scale blocks are (bm|1, 1) x (1, bn|1) depending on the grouping
    # layout; the product broadcasts against the (bm, bn) partial tile.
    sp = xsg_ref[...] * wsg_ref[...]
    acc_ref[...] += p * sp

    @pl.when(k == n_k - 1)
    def _done():
        unit = 2.0 ** (2 * (fmt.e_min - fmt.m))
        out_ref[...] = acc_ref[...] * (st_ref[0, 0] * unit)


def _nearest_legal_block(extent: int, block: int) -> int:
    """Largest divisor of ``extent`` that is <= ``block`` (for error text)."""
    for b in range(min(block, extent), 0, -1):
        if extent % b == 0:
            return b
    return 1


def _pad_rows(x: jax.Array, mult: int) -> jax.Array:
    p = (-x.shape[0]) % mult
    return jnp.pad(x, ((0, p), (0, 0))) if p else x


def _pad_cols(x: jax.Array, mult: int) -> jax.Array:
    p = (-x.shape[1]) % mult
    return jnp.pad(x, ((0, 0), (0, p))) if p else x


def mls_matmul_pallas(
    x_codes: jax.Array,
    x_sg: jax.Array,
    x_st: jax.Array,
    w_codes: jax.Array,
    w_sg: jax.Array,
    w_st: jax.Array,
    fmt: EMFormat,
    k_block: int = 128,
    block_m: int = 128,
    block_n: int = 128,
    grouping: str = "nc",
    interpret: bool | None = None,
) -> jax.Array:
    """Quantized-domain GEMM: x (M, K) @ w (K, N) -> fp32 (M, N).

    Group scales arrive in the compact layout of ``grouping`` (see
    :func:`sg_shapes`); ``"nc"`` is the paper's default: ``x_sg``
    (M, K/k_block), ``w_sg`` (K/k_block, N).

    Ragged ``M``/``N`` (not multiples of the clamped block) are handled by
    zero-padding the codes and slicing the output — exact, since padded
    codes decode to 0 and contribute nothing.  A ``K`` that is not a
    multiple of ``k_block`` is a group-layout mismatch (the scales would
    not line up) and raises ``ValueError``.
    """
    M, K = x_codes.shape
    K2, N = w_codes.shape
    assert K == K2, (x_codes.shape, w_codes.shape)
    if K % k_block:
        raise ValueError(
            f"mls_matmul_pallas: contraction K={K} of shape "
            f"({M}, {K}, {N}) is not a multiple of k_block={k_block} "
            f"(group boundaries would not align); nearest legal k_block "
            f"is {_nearest_legal_block(K, k_block)} — re-quantize with a "
            f"dividing k_block or pad K to a multiple before quantizing"
        )
    nkb = K // k_block
    block_m = min(block_m, M)
    block_n = min(block_n, N)
    exp_xsg, exp_wsg = sg_shapes(grouping, M, N, nkb)
    if tuple(x_sg.shape) != exp_xsg or tuple(w_sg.shape) != exp_wsg:
        raise ValueError(
            f"group-scale layout mismatch for grouping={grouping!r}: "
            f"expected x_sg {exp_xsg} / w_sg {exp_wsg}, got "
            f"{tuple(x_sg.shape)} / {tuple(w_sg.shape)}"
        )

    # Pad ragged M/N tails to block multiples (exact: zero codes decode to
    # 0; padded scale rows/cols are 1.0 so no inf/nan can leak into 0 * sp).
    pm, pn = (-M) % block_m, (-N) % block_n
    if pm:
        x_codes = _pad_rows(x_codes, block_m)
        if grouping in ("nc", "n"):
            x_sg = jnp.pad(x_sg, ((0, pm), (0, 0)), constant_values=1.0)
    if pn:
        w_codes = _pad_cols(w_codes, block_n)
        if grouping in ("nc", "n"):
            w_sg = jnp.pad(w_sg, ((0, 0), (0, pn)), constant_values=1.0)
    Mp, Np = M + pm, N + pn

    st = (x_st * w_st).astype(jnp.float32).reshape(1, 1)
    xsg_spec, wsg_spec = _sg_specs(grouping, block_m, block_n)
    kernel = functools.partial(_kernel, fmt=fmt, n_k=nkb)
    out = pl.pallas_call(
        kernel,
        grid=(Mp // block_m, Np // block_n, nkb),
        in_specs=[
            pl.BlockSpec((block_m, k_block), lambda i, j, k: (i, k)),
            xsg_spec,
            pl.BlockSpec((k_block, block_n), lambda i, j, k: (k, j)),
            wsg_spec,
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=resolve_interpret(interpret),
    )(x_codes, x_sg, w_codes, w_sg, st)
    return out[:M, :N] if (pm or pn) else out
