"""Pallas TPU kernel: low-bit tensor GEMM in the MLS quantized domain.

Implements the paper's low-bit convolution arithmetic (Sec. V-B, Eq. 6-8)
adapted to TPU as a tiled matmul:

* **Intra-group MACs** (Eq. 7): packed uint8 codes are decoded to signed
  integer fractions ``F`` (``|F| < 2^(M + 2^E - 1)``) and contracted over one
  ``k_block``-wide scaling group with an MXU ``dot``.  Products are at most
  ``2M + 2^(E+1) - 2`` bits (14 for the paper's ImageNet format ⟨2,4⟩), so
  fp32 accumulation over a 128-deep group is **bit-exact integer
  arithmetic** — the TPU-native analogue of the paper's int accumulator
  (fp32 is exact below 2^24; 14-bit products x 128 depth = 21 bits).
* **Inter-group combine** (Eq. 8): the partial sum of each group is scaled
  by ``S_p = s_g^x * s_g^w`` — a ⟨Eg,2⟩ value, i.e. a sum of <= 3 shifted
  copies in the paper's adder tree; here an exact fp32 multiply — and
  accumulated across groups in the fp32 output tile (the "TreeAdd" level).
* The tensor scales ``s_t^x * s_t^w`` multiply the output tile once
  (paper Sec. V-B: tensor-wise scale folded out of the MAC array).

Grid: ``(M/bm, N/bn, K/bk)`` with the contraction innermost; ``bk`` equals
the scaling-group width so group boundaries coincide with VMEM tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.formats import EMFormat


def _decode_frac(codes, fmt: EMFormat):
    """uint8 codes -> signed integer fractions as exact fp32 values."""
    c = codes.astype(jnp.int32)
    man = c & (2**fmt.m - 1)
    exp = (c >> fmt.m) & (2**fmt.e - 1)
    sign_bit = c >> (fmt.e + fmt.m)
    top = 2**fmt.e - 1
    is_denorm = exp == 0
    base = jnp.where(is_denorm, man, 2**fmt.m + man)
    shift = jnp.where(is_denorm, 0, top - exp)
    f = (base << shift).astype(jnp.float32)
    return jnp.where(sign_bit == 1, -f, f)


def _kernel(
    xc_ref, xsg_ref, wc_ref, wsg_ref, st_ref, out_ref, acc_ref, *, fmt, n_k
):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    fx = _decode_frac(xc_ref[...], fmt)  # (bm, bk) exact small ints
    fw = _decode_frac(wc_ref[...], fmt)  # (bk, bn)
    # Intra-group integer MACs on the MXU (exact in fp32, see module doc).
    p = jnp.dot(fx, fw, preferred_element_type=jnp.float32)  # (bm, bn)
    # Inter-group scale S_p = s_g^x ⊗ s_g^w (shift-add in HW, exact here).
    sp = xsg_ref[:, 0][:, None] * wsg_ref[0, :][None, :]
    acc_ref[...] += p * sp

    @pl.when(k == n_k - 1)
    def _done():
        unit = 2.0 ** (2 * (fmt.e_min - fmt.m))
        out_ref[...] = acc_ref[...] * (st_ref[0, 0] * unit)


def mls_matmul_pallas(
    x_codes: jax.Array,
    x_sg: jax.Array,
    x_st: jax.Array,
    w_codes: jax.Array,
    w_sg: jax.Array,
    w_st: jax.Array,
    fmt: EMFormat,
    k_block: int = 128,
    block_m: int = 128,
    block_n: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Quantized-domain GEMM: x (M, K) @ w (K, N) -> fp32 (M, N).

    ``x_sg``: (M, K/k_block) group scales; ``w_sg``: (K/k_block, N).
    """
    M, K = x_codes.shape
    K2, N = w_codes.shape
    assert K == K2 and K % k_block == 0
    nkb = K // k_block
    block_m = min(block_m, M)
    block_n = min(block_n, N)
    assert M % block_m == 0 and N % block_n == 0
    st = (x_st * w_st).astype(jnp.float32).reshape(1, 1)
    kernel = functools.partial(_kernel, fmt=fmt, n_k=nkb)
    return pl.pallas_call(
        kernel,
        grid=(M // block_m, N // block_n, nkb),
        in_specs=[
            pl.BlockSpec((block_m, k_block), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_m, 1), lambda i, j, k: (i, k)),
            pl.BlockSpec((k_block, block_n), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, block_n), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(x_codes, x_sg, w_codes, w_sg, st)
