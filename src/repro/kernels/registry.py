"""Registry of every shipped Pallas kernel entry point.

One table, three consumers:

* :mod:`repro.analysis.kernel_verify` traces each entry (forward and — for
  the training ops — the custom-VJP backward) and statically proves grid
  coverage and accumulator exactness for every ``pallas_call`` it finds;
* ``benchmarks/kernel_bench.py`` times the entries flagged ``bench`` on
  their example shapes, so the perf trail and the verifier agree on what
  "the shipped kernels" are;
* the shape-keyed autotuner (:mod:`repro.kernels.autotune`) tunes each
  entry's ``tune`` spec — the workload key ``(kind, shape, fmt,
  grouping)`` whose winner the persistent cache must hold (CI enforces
  this with ``python -m repro.kernels.autotune --check``).

Entries build *abstract* example arguments (``jax.ShapeDtypeStruct``), so
registering and tracing a kernel never allocates or executes anything;
``concrete_args`` materializes random inputs only when a benchmark asks.
"""
from __future__ import annotations

import dataclasses
from collections.abc import Callable

import jax
import jax.numpy as jnp

from repro.core.formats import FMT_IMAGENET
from repro.core.lowbit import QuantConfig
from .autotune import TuneSpec
from .implicit_conv import conv_geometry, conv_tune_dims
from .lowbit_conv import lowbit_conv_fused, lowbit_matmul_qd
from .mls_matmul import mls_matmul_pallas
from .mls_quantize import mls_quantize_pallas
from .ops import lowbit_matmul_fused

__all__ = ["KERNEL_REGISTRY", "KernelEntry"]


@dataclasses.dataclass(frozen=True)
class KernelEntry:
    """One verifiable/benchable Pallas kernel entry point.

    ``build()`` returns ``(fn, abstract_args)`` — a traceable callable and
    example ``ShapeDtypeStruct`` arguments.  ``needs_grad`` marks training
    ops whose custom-VJP backward GEMMs must be verified too (the verifier
    traces ``jax.vjp`` through them).  ``bench_tag`` names the example
    shape in benchmark rows (kept stable for the perf trail).  ``tune`` is
    the entry's autotuning workload (``None`` when another entry's spec
    already covers the same cache key — e.g. the raw-codes GEMM is tuned
    through the fused wrapper).
    """

    name: str
    description: str
    build: Callable[[], tuple[Callable, tuple]]
    needs_grad: bool = False
    bench: bool = True
    bench_tag: str = ""
    tune: TuneSpec | None = None

    def fn_and_args(self) -> tuple[Callable, tuple]:
        return self.build()

    def trace(self):
        """ClosedJaxpr of the forward (+ backward when ``needs_grad``)."""
        fn, avals = self.build()
        if self.needs_grad:
            def fwd_bwd(*args):
                y, vjp = jax.vjp(fn, *args)
                return y, vjp(jnp.ones_like(y))
            return jax.make_jaxpr(fwd_bwd)(*avals)
        return jax.make_jaxpr(fn)(*avals)

    def concrete_args(self, seed: int = 0) -> tuple:
        """Random concrete inputs matching the example abstract shapes."""
        _, avals = self.build()
        keys = jax.random.split(jax.random.key(seed), max(len(avals), 2))
        out = []
        for k, a in zip(keys, avals):
            if jnp.issubdtype(a.dtype, jnp.floating):
                out.append(jax.random.normal(k, a.shape, a.dtype))
            else:
                info = jnp.iinfo(a.dtype)
                out.append(jax.random.randint(
                    k, a.shape, 0, min(int(info.max), 255) + 1
                ).astype(a.dtype))
        return tuple(out)


_F32 = jnp.float32


def _build_quantize():
    def fn(x):
        return mls_quantize_pallas(x, FMT_IMAGENET, 128, interpret=True)
    return fn, (jax.ShapeDtypeStruct((256, 512), _F32),)


def _build_matmul():
    kb, M, K, N = 128, 256, 512, 256

    def fn(xc, xsg, xst, wc, wsg, wst):
        return mls_matmul_pallas(
            xc, xsg, xst, wc, wsg, wst, FMT_IMAGENET,
            k_block=kb, block_m=128, block_n=128, interpret=True,
        )
    avals = (
        jax.ShapeDtypeStruct((M, K), jnp.uint8),
        jax.ShapeDtypeStruct((M, K // kb), _F32),
        jax.ShapeDtypeStruct((), _F32),
        jax.ShapeDtypeStruct((K, N), jnp.uint8),
        jax.ShapeDtypeStruct((K // kb, N), _F32),
        jax.ShapeDtypeStruct((), _F32),
    )
    return fn, avals


def _build_matmul_fused():
    def fn(x, w):
        return lowbit_matmul_fused(x, w, None, fmt=FMT_IMAGENET,
                                   interpret=True)
    return fn, (jax.ShapeDtypeStruct((256, 512), _F32),
                jax.ShapeDtypeStruct((512, 256), _F32))


def _conv_cfg() -> QuantConfig:
    return QuantConfig(fmt=FMT_IMAGENET, stochastic=False, backend="pallas",
                       k_block=32, pallas_interpret=True)


def _build_conv_fused():
    cfg = _conv_cfg()

    def fn(x, w):
        return lowbit_conv_fused(x, w, None, (1, 1), "SAME", cfg)
    return fn, (jax.ShapeDtypeStruct((2, 16, 8, 8), _F32),
                jax.ShapeDtypeStruct((16, 16, 3, 3), _F32))


def _implicit_conv_cfg() -> QuantConfig:
    # k_block = cb*kh*kw = 4*3*3: legal implicit grouping for C=16 3x3 convs
    return QuantConfig(fmt=FMT_IMAGENET, stochastic=False, backend="pallas",
                       k_block=36, conv_impl="implicit",
                       pallas_interpret=True)


def _build_conv_implicit():
    cfg = _implicit_conv_cfg()

    def fn(x, w):
        return lowbit_conv_fused(x, w, None, (1, 1), "SAME", cfg)
    return fn, (jax.ShapeDtypeStruct((2, 16, 8, 8), _F32),
                jax.ShapeDtypeStruct((16, 16, 3, 3), _F32))


_ICONV_GEOM = conv_geometry((2, 16, 8, 8), (16, 16, 3, 3), (1, 1), "SAME")


def _build_matmul_qd():
    cfg = _conv_cfg()

    def fn(x, w):
        return lowbit_matmul_qd(x, w, None, cfg)
    return fn, (jax.ShapeDtypeStruct((64, 96), _F32),
                jax.ShapeDtypeStruct((96, 64), _F32))


KERNEL_REGISTRY: dict[str, KernelEntry] = {
    e.name: e
    for e in (
        KernelEntry(
            name="mls_quantize_pallas",
            description="fused MLS dynamic quantization (paper Alg. 2)",
            build=_build_quantize,
            bench_tag="256x512",
            tune=TuneSpec("quantize", (256, 512), FMT_IMAGENET, 128),
        ),
        KernelEntry(
            name="mls_matmul_pallas",
            description="quantized-domain GEMM (paper Eq. 6-8)",
            build=_build_matmul,
            bench=False,  # raw-codes timing is covered by the fused row
            bench_tag="256x512x256",
            tune=None,  # same cache key as lowbit_matmul_fused's spec
        ),
        KernelEntry(
            name="lowbit_matmul_fused",
            description="dynamic-quantize-both-operands fused GEMM",
            build=_build_matmul_fused,
            bench_tag="256x512x256",
            tune=TuneSpec("gemm", (256, 512, 256), FMT_IMAGENET, 128),
        ),
        KernelEntry(
            name="lowbit_conv_fused",
            description="im2col conv with fwd/wgrad/dgrad quantized GEMMs "
                        "(paper Alg. 1)",
            build=_build_conv_fused,
            needs_grad=True,
            bench_tag="2x16x8x8_o16k3",
            # the forward im2col GEMM of the example shape:
            # (N*OH*OW, C*kh*kw, O) = (2*8*8, 16*3*3, 16) at k_block=32
            tune=TuneSpec("gemm", (128, 144, 16), FMT_IMAGENET, 32),
        ),
        KernelEntry(
            name="lowbit_conv_implicit",
            description="implicit-GEMM conv, quantize fused into the GEMM "
                        "prologue (no materialized im2col)",
            build=_build_conv_implicit,
            needs_grad=True,
            bench_tag="2x16x8x8_o16k3",
            # conv specs key on the full geometry + k_block; the tuner
            # races im2col against implicit tilings at fixed numerics
            tune=TuneSpec("conv", conv_tune_dims(_ICONV_GEOM, 36),
                          FMT_IMAGENET, 36),
        ),
        KernelEntry(
            name="lowbit_matmul_qd",
            description="linear-layer training op, all three GEMMs "
                        "quantized-domain",
            build=_build_matmul_qd,
            needs_grad=True,
            bench=False,
            bench_tag="64x96x64",
            tune=TuneSpec("gemm", (64, 96, 64), FMT_IMAGENET, 32),
        ),
    )
}
