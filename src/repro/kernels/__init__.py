"""Pallas TPU kernels for the MLS low-bit hot loops.

* ``mls_quantize`` — fused dynamic quantization (paper Alg. 2)
* ``mls_matmul``   — quantized-domain GEMM with exact intra-group integer
  accumulation and shift-add inter-group scaling (paper Eq. 6-8)
* ``ops``          — jit'd public wrappers
* ``ref``          — pure-jnp oracles used by the test suite
"""
from .mls_quantize import mls_quantize_pallas
from .mls_matmul import mls_matmul_pallas
from .ops import lowbit_matmul_fused

__all__ = ["mls_quantize_pallas", "mls_matmul_pallas", "lowbit_matmul_fused"]
