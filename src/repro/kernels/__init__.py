"""Pallas TPU kernels for the MLS low-bit hot loops.

* ``mls_quantize`` — fused dynamic quantization (paper Alg. 2)
* ``mls_matmul``   — quantized-domain GEMM with exact intra-group integer
  accumulation and shift-add inter-group scaling (paper Eq. 6-8)
* ``lowbit_conv``  — im2col/implicit-GEMM conv + matmul training ops with
  all three GEMMs (fwd / wgrad / dgrad) in the quantized domain (Alg. 1)
* ``ops``          — jit'd public wrappers
* ``ref``          — pure-jnp oracles used by the test suite
* ``registry``     — ``KERNEL_REGISTRY``: the one table of shipped Pallas
  entry points shared by the static verifier, the benchmarks, and the
  autotuner
* ``autotune``     — shape-keyed block-size autotuner with a persistent
  tuning cache (``python -m repro.kernels.autotune``)
* ``runtime``      — the process-wide interpret-mode switch
  (``REPRO_PALLAS_INTERPRET``)
"""
from .autotune import BlockConfig, TuneSpec, resolve_block_config
from .implicit_conv import (
    ConvGeom,
    conv_geometry,
    im2col_conv_bytes,
    implicit_compatible,
    implicit_conv_bytes,
    implicit_conv_forward,
    resolve_conv_impl,
)
from .mls_quantize import mls_quantize_pallas
from .mls_matmul import mls_matmul_pallas
from .ops import lowbit_matmul_fused
from .lowbit_conv import (
    conv_fused_grads_ref,
    lowbit_conv_fused,
    lowbit_conv_fused_ref,
    lowbit_matmul_qd,
    matmul_qd_grads_ref,
    matmul_qd_ref,
    qd_gemm,
)
from .registry import KERNEL_REGISTRY, KernelEntry
from .runtime import INTERPRET_ENV_VAR, default_interpret, resolve_interpret

__all__ = [
    "KERNEL_REGISTRY",
    "KernelEntry",
    "BlockConfig",
    "TuneSpec",
    "resolve_block_config",
    "INTERPRET_ENV_VAR",
    "default_interpret",
    "resolve_interpret",
    "ConvGeom",
    "conv_geometry",
    "im2col_conv_bytes",
    "implicit_compatible",
    "implicit_conv_bytes",
    "implicit_conv_forward",
    "resolve_conv_impl",
    "mls_quantize_pallas",
    "mls_matmul_pallas",
    "lowbit_matmul_fused",
    "lowbit_conv_fused",
    "lowbit_conv_fused_ref",
    "conv_fused_grads_ref",
    "lowbit_matmul_qd",
    "matmul_qd_ref",
    "matmul_qd_grads_ref",
    "qd_gemm",
]
