"""Pallas TPU kernel: fused MLS dynamic quantization (paper Alg. 2).

One pass over the operand computes group maxima, the hardware-friendly
``<Eg,Mg>`` group scales (ceil-rounded), and the packed ``<Ex,Mx>`` element
codes with stochastic rounding — writing **1 byte per element** plus one
scale per ``k_block`` elements back to HBM (vs 4 bytes for the fp32 input):
the memory-traffic reduction that makes dynamic quantization cheap on TPU.

The tensor-wise scale ``s_t`` is a global reduction and is computed ahead of
the kernel (a cheap fused max-reduce); it enters the kernel via SMEM.

Grid: one program per ``block_m`` rows; each program statically loops over
the ``K // k_block`` scaling groups of its rows, keeping the whole row block
in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.formats import EMFormat, GS_FMT_DEFAULT

DEFAULT_BLOCK_M = 256


def _exponent_fraction(x):
    """Bit-exact Exponent/Fraction on fp32 (kernel-local copy)."""
    bits = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.int32)
    raw_exp = (bits >> 23) & 0xFF
    man_bits = bits & 0x7FFFFF
    bad = raw_exp == 0  # zero / fp32-subnormal -> treat as zero
    e = jnp.where(bad, jnp.int32(-(2**30)), raw_exp - 127)
    frac = jax.lax.bitcast_convert_type(man_bits | (127 << 23), jnp.int32)
    frac = jnp.where(bad, 0.0, jax.lax.bitcast_convert_type(frac, jnp.float32))
    return e, frac


def _quantize_block(x, r_u8, s_t, fmt: EMFormat, gs_fmt: EMFormat):
    """Quantize one (block_m, k_block) group column. Returns (codes, s_g)."""
    absx = jnp.abs(x)
    sign_bit = (x < 0).astype(jnp.int32)

    # ---- group scale (one per row of the block), Alg. 2 l.2-8 ------------
    s_r = jnp.max(absx, axis=1, keepdims=True)  # (bm, 1)
    s_gf = s_r / s_t
    eg_min = max(gs_fmt.e_min, -120)
    e_g, frac_g = _exponent_fraction(s_gf)
    too_small = e_g < eg_min
    e_g = jnp.clip(e_g, eg_min, 0)
    frac_g = jnp.where(too_small, 1.0, frac_g)
    man_g = jnp.ceil((frac_g - 1.0) * 2.0**gs_fmt.m)
    overflow = man_g >= 2**gs_fmt.m
    man_g = jnp.where(overflow, 0.0, man_g)
    e_g = jnp.clip(jnp.where(overflow, e_g + 1, e_g), eg_min, 0)
    s_g = (1.0 + man_g * 2.0**-gs_fmt.m) * jnp.exp2(e_g.astype(jnp.float32))

    # ---- elements, Alg. 2 l.9-16 ------------------------------------------
    denom = s_t * s_g
    x_f = jnp.where(denom > 0, absx / jnp.where(denom > 0, denom, 1.0), 0.0)
    e_x, _ = _exponent_fraction(x_f)
    e_eff = jnp.clip(e_x, fmt.e_min, -1)
    step = jnp.exp2((e_eff - fmt.m).astype(jnp.float32))
    r = (r_u8.astype(jnp.float32) + 0.5) / 256.0 - 0.5
    q = jnp.floor(x_f / step + r + 0.5)
    qmax = jnp.where(e_eff == -1, 2.0 ** (fmt.m + 1) - 1.0, 2.0 ** (fmt.m + 1))
    q = jnp.clip(q, 0.0, qmax)
    xbar = q * step

    e2, frac2 = _exponent_fraction(xbar)
    is_normal = e2 >= fmt.e_min
    man = jnp.where(
        is_normal,
        jnp.floor((frac2 - 1.0) * 2.0**fmt.m + 0.5),
        jnp.floor(xbar * 2.0 ** (fmt.m - fmt.e_min) + 0.5),
    ).astype(jnp.int32)
    exp_stored = jnp.where(is_normal, -e2, 0)
    codes = (
        (sign_bit << (fmt.e + fmt.m)) | (exp_stored << fmt.m) | man
    ).astype(jnp.uint8)
    return codes, s_g[:, 0]


def _kernel(x_ref, r_ref, st_ref, codes_ref, sg_ref, *, fmt, gs_fmt, k_block):
    s_t = st_ref[0, 0]
    n_groups = x_ref.shape[1] // k_block
    for g in range(n_groups):  # static loop over scaling groups
        sl = pl.dslice(g * k_block, k_block)
        codes, s_g = _quantize_block(
            x_ref[:, sl], r_ref[:, sl], s_t, fmt, gs_fmt
        )
        codes_ref[:, sl] = codes
        sg_ref[:, pl.dslice(g, 1)] = s_g[:, None]


def mls_quantize_pallas(
    x: jax.Array,
    fmt: EMFormat,
    k_block: int = 128,
    gs_fmt: EMFormat = GS_FMT_DEFAULT,
    key: jax.Array | None = None,
    block_m: int = DEFAULT_BLOCK_M,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Quantize a 2-D ``(M, K)`` operand to packed MLS codes.

    Returns ``(codes uint8 (M, K), s_g f32 (M, K/k_block), s_t f32 scalar)``.
    """
    M, K = x.shape
    assert K % k_block == 0, (K, k_block)
    block_m = min(block_m, M)
    assert M % block_m == 0, (M, block_m)
    x = x.astype(jnp.float32)
    s_t = jnp.max(jnp.abs(x))
    s_t = jnp.where(s_t > 0, s_t, 1.0).reshape(1, 1)
    if key is not None:
        r_u8 = jax.random.randint(key, x.shape, 0, 256, dtype=jnp.int32).astype(
            jnp.uint8
        )
    else:
        r_u8 = jnp.full(x.shape, 127, dtype=jnp.uint8)  # r = -0.002 ~ nearest
    nkb = K // k_block
    kernel = functools.partial(_kernel, fmt=fmt, gs_fmt=gs_fmt, k_block=k_block)
    codes, s_g = pl.pallas_call(
        kernel,
        grid=(M // block_m,),
        in_specs=[
            pl.BlockSpec((block_m, K), lambda i: (i, 0)),
            pl.BlockSpec((block_m, K), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_m, K), lambda i: (i, 0)),
            pl.BlockSpec((block_m, nkb), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M, K), jnp.uint8),
            jax.ShapeDtypeStruct((M, nkb), jnp.float32),
        ],
        interpret=interpret,
    )(x, r_u8, s_t)
    return codes, s_g, s_t[0, 0]
