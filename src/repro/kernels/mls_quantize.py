"""Pallas TPU kernel: fused MLS dynamic quantization (paper Alg. 2).

One pass over the operand computes group maxima, the hardware-friendly
``<Eg,Mg>`` group scales (ceil-rounded), and the packed ``<Ex,Mx>`` element
codes with stochastic rounding — writing **1 byte per element** plus one
scale per group back to HBM (vs 4 bytes for the fp32 input): the
memory-traffic reduction that makes dynamic quantization cheap on TPU.

The tensor-wise scale ``s_t`` is a global reduction and is computed ahead of
the kernel (a cheap fused max-reduce); it enters the kernel via SMEM.

**Grouping** (paper Table IV) selects the scaling-group layout of a 2-D
``(M, K)`` operand (the GEMM orientation: rows x contraction):

* ``"nc"`` — one group per (row, ``k_block``-wide contraction block);
  scales (M, K/k_block), computed inside the kernel (the default).
* ``"n"``  — one group per row; scales (M, 1), computed inside the kernel
  (a single full-width group per row block).
* ``"c"``  — one group per contraction block shared by *all* rows; scales
  (1, K/k_block).  The group max crosses row-block programs, so the compact
  scales are precomputed by a fused XLA reduction (same exact
  ``quantize_group_scale`` math) and the kernel only quantizes elements.
* ``"none"`` — tensor-wise only; group scales are exactly 1 (shape (1, 1)).

Grid: one program per ``block_m`` rows; each program statically loops over
the scaling groups of its rows, keeping the whole row block in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.formats import EMFormat, GS_FMT_DEFAULT
from repro.core.quantize import quantize_group_scale
from .runtime import resolve_interpret

DEFAULT_BLOCK_M = 256

GROUPINGS = ("nc", "c", "n", "none")


def _exponent_fraction(x):
    """Bit-exact Exponent/Fraction on fp32 (kernel-local copy)."""
    bits = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.int32)
    raw_exp = (bits >> 23) & 0xFF
    man_bits = bits & 0x7FFFFF
    bad = raw_exp == 0  # zero / fp32-subnormal -> treat as zero
    e = jnp.where(bad, jnp.int32(-(2**30)), raw_exp - 127)
    frac = jax.lax.bitcast_convert_type(man_bits | (127 << 23), jnp.int32)
    frac = jnp.where(bad, 0.0, jax.lax.bitcast_convert_type(frac, jnp.float32))
    return e, frac


def _element_codes(x, r_u8, denom, fmt: EMFormat):
    """Packed codes for one block given its scale denominator (Alg. 2
    l.9-16).  ``denom`` broadcasts against ``x`` ((bm, 1), (1,) or scalar)."""
    absx = jnp.abs(x)
    sign_bit = (x < 0).astype(jnp.int32)
    x_f = jnp.where(denom > 0, absx / jnp.where(denom > 0, denom, 1.0), 0.0)
    e_x, _ = _exponent_fraction(x_f)
    e_eff = jnp.clip(e_x, fmt.e_min, -1)
    step = jnp.exp2((e_eff - fmt.m).astype(jnp.float32))
    r = (r_u8.astype(jnp.float32) + 0.5) / 256.0 - 0.5
    q = jnp.floor(x_f / step + r + 0.5)
    qmax = jnp.where(e_eff == -1, 2.0 ** (fmt.m + 1) - 1.0, 2.0 ** (fmt.m + 1))
    q = jnp.clip(q, 0.0, qmax)
    xbar = q * step

    e2, frac2 = _exponent_fraction(xbar)
    is_normal = e2 >= fmt.e_min
    man = jnp.where(
        is_normal,
        jnp.floor((frac2 - 1.0) * 2.0**fmt.m + 0.5),
        jnp.floor(xbar * 2.0 ** (fmt.m - fmt.e_min) + 0.5),
    ).astype(jnp.int32)
    exp_stored = jnp.where(is_normal, -e2, 0)
    return (
        (sign_bit << (fmt.e + fmt.m)) | (exp_stored << fmt.m) | man
    ).astype(jnp.uint8)


def _quantize_block(x, r_u8, s_t, fmt: EMFormat, gs_fmt: EMFormat):
    """Quantize one (block_m, group_width) group column -> (codes, s_g)."""
    absx = jnp.abs(x)

    # ---- group scale (one per row of the block), Alg. 2 l.2-8 ------------
    s_r = jnp.max(absx, axis=1, keepdims=True)  # (bm, 1)
    s_gf = s_r / s_t
    eg_min = max(gs_fmt.e_min, -120)
    e_g, frac_g = _exponent_fraction(s_gf)
    too_small = e_g < eg_min
    e_g = jnp.clip(e_g, eg_min, 0)
    frac_g = jnp.where(too_small, 1.0, frac_g)
    man_g = jnp.ceil((frac_g - 1.0) * 2.0**gs_fmt.m)
    overflow = man_g >= 2**gs_fmt.m
    man_g = jnp.where(overflow, 0.0, man_g)
    e_g = jnp.clip(jnp.where(overflow, e_g + 1, e_g), eg_min, 0)
    s_g = (1.0 + man_g * 2.0**-gs_fmt.m) * jnp.exp2(e_g.astype(jnp.float32))

    codes = _element_codes(x, r_u8, s_t * s_g, fmt)
    return codes, s_g[:, 0]


def _kernel_rowwise(
    x_ref, r_ref, st_ref, codes_ref, sg_ref, *, fmt, gs_fmt, group_width
):
    """In-kernel group scales: ``"nc"`` (group_width == k_block) and
    ``"n"`` (group_width == K: one group per row)."""
    s_t = st_ref[0, 0]
    n_groups = x_ref.shape[1] // group_width
    for g in range(n_groups):  # static loop over scaling groups
        sl = pl.dslice(g * group_width, group_width)
        codes, s_g = _quantize_block(
            x_ref[:, sl], r_ref[:, sl], s_t, fmt, gs_fmt
        )
        codes_ref[:, sl] = codes
        sg_ref[:, pl.dslice(g, 1)] = s_g[:, None]


def _kernel_given_sg(
    x_ref, r_ref, st_ref, sg_ref, codes_ref, *, fmt, k_block
):
    """Element quantization against precomputed compact scales (``"c"``:
    sg (1, K/k_block); ``"none"``: sg (1, 1) == 1)."""
    s_t = st_ref[0, 0]
    n_groups = x_ref.shape[1] // k_block
    per_group = sg_ref.shape[1] > 1
    for g in range(n_groups):
        sl = pl.dslice(g * k_block, k_block)
        s_g = sg_ref[0, g] if per_group else sg_ref[0, 0]
        codes_ref[:, sl] = _element_codes(
            x_ref[:, sl], r_ref[:, sl], s_t * s_g, fmt
        )


def mls_quantize_pallas(
    x: jax.Array,
    fmt: EMFormat,
    k_block: int = 128,
    gs_fmt: EMFormat = GS_FMT_DEFAULT,
    key: jax.Array | None = None,
    block_m: int = DEFAULT_BLOCK_M,
    interpret: bool | None = None,
    grouping: str = "nc",
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Quantize a 2-D ``(M, K)`` operand to packed MLS codes.

    Returns ``(codes uint8 (M, K), s_g f32, s_t f32 scalar)`` with ``s_g``
    in the compact layout of ``grouping`` (see the module docstring):
    (M, K/k_block), (1, K/k_block), (M, 1) or (1, 1).

    A ragged row count (``M`` not a multiple of the clamped ``block_m``) is
    zero-padded and sliced back — exact: zero rows quantize to zero codes
    and never contribute to any cross-row group maximum.  ``K`` must be a
    multiple of ``k_block`` (group boundaries), else ``ValueError``.
    """
    if grouping not in GROUPINGS:
        raise ValueError(
            f"unknown grouping {grouping!r}; expected one of {GROUPINGS}")
    M, K = x.shape
    if K % k_block:
        raise ValueError(
            f"mls_quantize_pallas: K={K} not a multiple of k_block="
            f"{k_block}; pad the operand (the fused ops do) or pick a "
            f"dividing k_block"
        )
    block_m = min(block_m, M)
    interpret = resolve_interpret(interpret)
    x = x.astype(jnp.float32)
    s_t = jnp.max(jnp.abs(x))
    s_t = jnp.where(s_t > 0, s_t, 1.0).reshape(1, 1)
    if key is not None:
        r_u8 = jax.random.randint(key, x.shape, 0, 256, dtype=jnp.int32).astype(
            jnp.uint8
        )
    else:
        r_u8 = jnp.full(x.shape, 127, dtype=jnp.uint8)  # r = -0.002 ~ nearest

    pm = (-M) % block_m
    if pm:
        x = jnp.pad(x, ((0, pm), (0, 0)))
        r_u8 = jnp.pad(r_u8, ((0, pm), (0, 0)), constant_values=127)
    Mp = M + pm
    nkb = K // k_block

    if grouping in ("nc", "n"):
        group_width = k_block if grouping == "nc" else K
        n_sg = nkb if grouping == "nc" else 1
        kernel = functools.partial(
            _kernel_rowwise, fmt=fmt, gs_fmt=gs_fmt, group_width=group_width)
        codes, s_g = pl.pallas_call(
            kernel,
            grid=(Mp // block_m,),
            in_specs=[
                pl.BlockSpec((block_m, K), lambda i: (i, 0)),
                pl.BlockSpec((block_m, K), lambda i: (i, 0)),
                pl.BlockSpec((1, 1), lambda i: (0, 0)),
            ],
            out_specs=[
                pl.BlockSpec((block_m, K), lambda i: (i, 0)),
                pl.BlockSpec((block_m, n_sg), lambda i: (i, 0)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((Mp, K), jnp.uint8),
                jax.ShapeDtypeStruct((Mp, n_sg), jnp.float32),
            ],
            interpret=interpret,
        )(x, r_u8, s_t)
        if pm:
            codes, s_g = codes[:M], s_g[:M]
        return codes, s_g, s_t[0, 0]

    # "c" / "none": compact scales precomputed (exact quantize_group_scale
    # math; for "c" the group max crosses row-block programs).
    if grouping == "c":
        s_r = jnp.max(jnp.abs(x), axis=0).reshape(1, nkb, k_block).max(axis=2)
        s_g, _, _ = quantize_group_scale(s_r / s_t[0, 0], gs_fmt)  # (1, nkb)
    else:  # "none"
        s_g = jnp.ones((1, 1), jnp.float32)
    n_sg = s_g.shape[1]
    kernel = functools.partial(_kernel_given_sg, fmt=fmt, k_block=k_block)
    codes = pl.pallas_call(
        kernel,
        grid=(Mp // block_m,),
        in_specs=[
            pl.BlockSpec((block_m, K), lambda i: (i, 0)),
            pl.BlockSpec((block_m, K), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, n_sg), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, K), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Mp, K), jnp.uint8),
        interpret=interpret,
    )(x, r_u8, s_t, s_g)
    return (codes[:M] if pm else codes), s_g, s_t[0, 0]
