"""Quantized-domain low-bit convolution via im2col / implicit GEMM.

This is the training hot path for paper Alg. 1 running on the **real**
quantized-domain Pallas pipeline (``mls_quantize_pallas`` ->
``mls_matmul_pallas``) instead of fake-quant + ``lax.conv``.  All three
training convolutions are lowered to MLS GEMMs over the im2col layout:

    forward : Z  = Cols(qA) @ qW            (Alg. 1 l.4)
    wgrad   : G  = Cols(qA)^T @ qE          (Alg. 1 l.13)
    dgrad   : dA = col2im(qE @ qW^T), STE   (Alg. 1 l.15-16)

Each GEMM dynamically quantizes its operands with scaling groups of
``k_block`` elements **along its own contraction axis**, so group boundaries
coincide with the GEMM's VMEM contraction tiles (the matmul analogue of the
paper's (n, c) conv grouping; the contraction axis plays the role of the
input channel).  That means the three GEMMs use three different group
layouts of the same logical operands — the per-GEMM dynamic-quantization
cost the paper budgets in Alg. 1.

Every function here is written against an abstract (quantize, matmul)
backend pair.  ``lowbit_conv_fused`` binds the Pallas kernels;
``lowbit_conv_fused_ref`` / ``conv_fused_grads_ref`` bind the pure-jnp
oracles from :mod:`repro.kernels.ref` through the *same* layout/padding
code, so kernel-vs-oracle tests assert bit-identical outputs and gradients.

``QuantConfig.grouping`` is honored end to end: each GEMM quantizes its
operands in the matmul analogue of the paper's Table IV layout ("nc" per
(row, k-block), "c" per k-block shared across rows, "n" per row/column,
"none" tensor-wise) and the Pallas GEMM consumes the matching compact
group-scale layout.  Output tilings left unset on the config resolve
through the autotuner cache (:mod:`repro.kernels.autotune`).

The forward conv has two interchangeable lowerings on the pallas backend:
``"im2col"`` (materialized patch matrix, any ``k_block``) and
``"implicit"`` (:mod:`repro.kernels.implicit_conv`: a single fused kernel
that walks the NCHW activation and quantizes in the GEMM prologue — no
patch matrix, activations read from HBM once).  ``QuantConfig.conv_impl``
/ the ``REPRO_CONV_IMPL`` env pick explicitly; ``"auto"`` resolves through
the tuned cache and falls back to implicit-when-legal.  The implicit
layout requires ``k_block = cb*kh*kw`` with ``cb | C`` (groups are whole
channels' taps), so impl selection never changes quantization semantics.
When it is active with ``grouping="none"`` and deterministic rounding,
the weight-grad GEMM *reuses the forward activation codes*: tensor-wise
quantization commutes with the patch gather, so the codes are gathered
(1 byte/element) instead of re-quantizing the fp32 patch matrix.  Other
groupings re-quantize because the wgrad contraction runs along the patch
axis — a different group layout than the forward's.
"""
from __future__ import annotations

from functools import partial
from collections.abc import Callable
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.formats import EMFormat, GS_FMT_DEFAULT
from repro.core.lowbit import QuantConfig, _maybe_key
from .implicit_conv import (
    conv_geometry,
    covered_tensor_scale,
    elementwise_codes,
    implicit_conv_forward,
    patches_u8,
    resolve_conv_blocks,
    resolve_conv_impl,
)
from .mls_matmul import mls_matmul_pallas
from .mls_quantize import mls_quantize_pallas
from .ref import mls_matmul_ref, quantize_ref

__all__ = [
    "qd_gemm",
    "lowbit_conv_fused",
    "lowbit_conv_fused_ref",
    "conv_fused_grads_ref",
    "lowbit_matmul_qd",
    "matmul_qd_ref",
    "matmul_qd_grads_ref",
]


# ---------------------------------------------------------------------------
# Backend: (quantize, matmul) implementation pair
# ---------------------------------------------------------------------------
class QDBackend(NamedTuple):
    """A quantized-domain GEMM implementation.

    ``quantize(x2d, fmt, k_block, gs_fmt, key, block_m, grouping, interpret)``
        -> (codes u8 (M, K), s_g f32 in the grouping's compact layout,
            s_t f32 scalar)
    ``matmul(xc, xsg, xst, wc, wsg, wst, fmt, k_block, bm, bn, grouping,
    interpret)`` -> f32 (M, N)
    """

    quantize: Callable
    matmul: Callable


def _pallas_quantize(x2d, fmt, k_block, gs_fmt, key, block_m, grouping,
                     interpret):
    return mls_quantize_pallas(
        x2d, fmt, k_block, gs_fmt, key, block_m=block_m, interpret=interpret,
        grouping=grouping,
    )


def _pallas_matmul(xc, xsg, xst, wc, wsg, wst, fmt, k_block, bm, bn, grouping,
                   interpret):
    return mls_matmul_pallas(
        xc, xsg, xst, wc, wsg, wst, fmt,
        k_block=k_block, block_m=bm, block_n=bn, grouping=grouping,
        interpret=interpret,
    )


def _ref_quantize(x2d, fmt, k_block, gs_fmt, key, block_m, grouping,
                  interpret):
    # mirror the kernel's stochastic-rounding source exactly: uint8 draws
    # from `key`, and the r = 127 (~nearest) constant when key is None.
    if key is None:
        r_u8 = jnp.full(x2d.shape, 127, dtype=jnp.uint8)
    else:
        r_u8 = jax.random.randint(key, x2d.shape, 0, 256, dtype=jnp.int32).astype(
            jnp.uint8
        )
    return quantize_ref(
        x2d, fmt, k_block, gs_fmt=gs_fmt, r_u8=r_u8, grouping=grouping
    )


def _ref_matmul(xc, xsg, xst, wc, wsg, wst, fmt, k_block, bm, bn, grouping,
                interpret):
    return mls_matmul_ref(xc, xsg, xst, wc, wsg, wst, fmt, k_block)


PALLAS_BACKEND = QDBackend(_pallas_quantize, _pallas_matmul)
REF_BACKEND = QDBackend(_ref_quantize, _ref_matmul)


def _interpret(cfg: QuantConfig) -> bool | None:
    """Per-config interpret override; ``None`` defers to the process-wide
    switch (:func:`repro.kernels.runtime.resolve_interpret`)."""
    return cfg.pallas_interpret


# ---------------------------------------------------------------------------
# Core quantized-domain GEMM with padding to tile/group multiples
# ---------------------------------------------------------------------------
def _pad_to(x: jax.Array, mult0: int, mult1: int) -> jax.Array:
    p0 = (-x.shape[0]) % mult0
    p1 = (-x.shape[1]) % mult1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


def qd_gemm(
    x2d: jax.Array,
    w2d: jax.Array,
    key_x: jax.Array | None,
    key_w: jax.Array | None,
    *,
    fmt: EMFormat,
    gs_fmt: EMFormat = GS_FMT_DEFAULT,
    k_block: int = 128,
    block_m: int | None = None,
    block_n: int | None = None,
    grouping: str = "nc",
    backend: QDBackend = PALLAS_BACKEND,
    interpret: bool | None = None,
) -> jax.Array:
    """Dynamically quantize ``x (M,K)`` / ``w (K,N)`` and contract.

    Scaling groups follow ``grouping`` on both operands (each along its own
    contraction axis).  Output tiles left at ``None`` resolve through the
    autotuner cache on the *logical* (M, K, N) shape (explicit override >
    cache hit > proven-legal default).  Both operands are zero-padded to
    tile/group multiples (exact: padded codes are 0 so their products
    vanish, zero rows/columns are cropped from the output, and zero rows
    never raise a cross-row group maximum).  The weight operand is
    quantized transposed so its scaling groups run along K, then its
    codes/scales are transposed into the (K, N)-oriented layout the GEMM
    consumes (a plain transpose is exactly the GEMM-side compact layout
    for every grouping).
    """
    M, K = x2d.shape
    K2, N = w2d.shape
    assert K == K2, (x2d.shape, w2d.shape)
    if block_m is None or block_n is None:
        from .autotune import resolve_block_config  # lazy: avoids a cycle

        cfg = resolve_block_config(
            "gemm", (M, K, N), fmt, grouping,
            k_block=k_block, block_m=block_m, block_n=block_n,
        )
        block_m, block_n = cfg.block_m, cfg.block_n
    xp = _pad_to(x2d.astype(jnp.float32), block_m, k_block)
    wp = _pad_to(w2d.astype(jnp.float32), k_block, block_n)
    xc, xsg, xst = backend.quantize(
        xp, fmt, k_block, gs_fmt, key_x, block_m, grouping, interpret
    )
    wc, wsgT, wst = backend.quantize(
        wp.T, fmt, k_block, gs_fmt, key_w, block_n, grouping, interpret
    )
    y = backend.matmul(
        xc, xsg, xst, wc.T, wsgT.T, wst, fmt, k_block, block_m, block_n,
        grouping, interpret,
    )
    return y[:M, :N]


# ---------------------------------------------------------------------------
# im2col layout
# ---------------------------------------------------------------------------
def _im2col(x: jax.Array, ksize: tuple[int, int], stride, padding):
    """NCHW -> (N*OH*OW, C*kh*kw) patch matrix (+ output spatial dims).

    Feature order is (c, kh, kw), matching ``w.reshape(O, C*kh*kw)`` of an
    OIHW weight, so conv == cols @ w_mat.T.
    """
    p = jax.lax.conv_general_dilated_patches(
        x.astype(jnp.float32), ksize, stride, padding,
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    n, ckk, oh, ow = p.shape
    return p.transpose(0, 2, 3, 1).reshape(n * oh * ow, ckk), (n, oh, ow)


def _col2im(dcols: jax.Array, x_shape, ksize, stride, padding, out_hw):
    """Exact transpose of :func:`_im2col` (scatter-add of patch cotangents)."""
    n, oh, ow = out_hw
    ckk = dcols.shape[1]
    dpatch = dcols.reshape(n, oh, ow, ckk).transpose(0, 3, 1, 2)

    def patches(a):
        return jax.lax.conv_general_dilated_patches(
            a, ksize, stride, padding,
            dimension_numbers=("NCHW", "OIHW", "NCHW"),
        )

    transpose = jax.linear_transpose(
        patches, jax.ShapeDtypeStruct(x_shape, jnp.float32)
    )
    (dx,) = transpose(dpatch)
    return dx


# ---------------------------------------------------------------------------
# Fused conv: forward and backward pipelines (backend-parameterized)
# ---------------------------------------------------------------------------
def _gemm_kwargs(cfg: QuantConfig, backend: QDBackend):
    return dict(
        fmt=cfg.fmt, gs_fmt=cfg.gs_fmt, k_block=cfg.k_block,
        block_m=cfg.block_m, block_n=cfg.block_n, grouping=cfg.grouping,
        backend=backend, interpret=_interpret(cfg),
    )


def _conv_fwd_impl(x, w, key, stride, padding, cfg, backend):
    o = w.shape[0]
    if backend is PALLAS_BACKEND:
        geom = conv_geometry(x.shape, w.shape, stride, padding)
        if resolve_conv_impl(geom, cfg) == "implicit":
            bh, bn = resolve_conv_blocks(geom, cfg)
            return implicit_conv_forward(
                x, w, _maybe_key(key, cfg, 0), _maybe_key(key, cfg, 1),
                stride, padding, fmt=cfg.fmt, gs_fmt=cfg.gs_fmt,
                k_block=cfg.k_block, bh=bh, block_n=bn,
                grouping=cfg.grouping, interpret=_interpret(cfg),
            )
    cols, (n, oh, ow) = _im2col(x, w.shape[2:], stride, padding)
    wmat = w.reshape(o, -1).T.astype(jnp.float32)  # (C*kh*kw, O)
    y2d = qd_gemm(
        cols, wmat, _maybe_key(key, cfg, 0), _maybe_key(key, cfg, 1),
        **_gemm_kwargs(cfg, backend),
    )
    return y2d.reshape(n, oh, ow, o).transpose(0, 3, 1, 2)


def _qd_gemm_precoded_x(
    xc: jax.Array, x_st: jax.Array, w2d: jax.Array, key_w, *, fmt, gs_fmt,
    k_block, block_m, block_n, interpret,
):
    """`qd_gemm` with the x operand already in u8 codes (tensor-wise
    scale ``x_st``, grouping "none") — the forward-code-reuse wgrad path.
    Padding/quantize/matmul mirror `qd_gemm` exactly, so the result is
    bit-identical to re-quantizing the fp32 operand with grouping "none"
    and deterministic rounding."""
    M, K = xc.shape
    K2, N = w2d.shape
    assert K == K2, (xc.shape, w2d.shape)
    if block_m is None or block_n is None:
        from .autotune import resolve_block_config  # lazy: avoids a cycle

        bc = resolve_block_config(
            "gemm", (M, K, N), fmt, "none",
            k_block=k_block, block_m=block_m, block_n=block_n,
        )
        block_m, block_n = bc.block_m, bc.block_n
    xcp = _pad_to(xc, block_m, k_block)  # zero codes decode to 0 — exact
    wp = _pad_to(w2d.astype(jnp.float32), k_block, block_n)
    wc, wsgT, wst = _pallas_quantize(
        wp.T, fmt, k_block, gs_fmt, key_w, block_n, "none", interpret
    )
    ones = jnp.ones((1, 1), jnp.float32)
    y = _pallas_matmul(
        xcp, ones, x_st, wc.T, wsgT.T, wst, fmt, k_block, block_m, block_n,
        "none", interpret,
    )
    return y[:M, :N]


def _conv_bwd_impl(x, w, g, key, stride, padding, cfg, backend):
    o = w.shape[0]
    ksize = w.shape[2:]
    geom = conv_geometry(x.shape, w.shape, stride, padding)
    n, oh, ow = geom.n, geom.oh, geom.ow
    e2d = g.transpose(0, 2, 3, 1).reshape(-1, o).astype(jnp.float32)
    wmat = w.reshape(o, -1).astype(jnp.float32)  # (O, C*kh*kw)
    kw = _gemm_kwargs(cfg, backend)
    # G = Cols(qA)^T @ qE: contraction over the N*OH*OW patches (Alg. 1 l.13)
    reuse_codes = (
        backend is PALLAS_BACKEND
        and cfg.grouping == "none"
        and _maybe_key(key, cfg, 2) is None
        and resolve_conv_impl(geom, cfg) == "implicit"
    )
    if reuse_codes:
        # Tensor-wise quantization commutes with the patch gather, so the
        # forward activation codes are gathered as u8 instead of
        # re-quantizing the fp32 patch matrix (bit-identical to qd_gemm on
        # cols.T with grouping "none" + nearest rounding).
        s_t, xp = covered_tensor_scale(x, geom)
        colsT_codes = patches_u8(elementwise_codes(xp, s_t, cfg.fmt), geom).T
        dwmat = _qd_gemm_precoded_x(
            colsT_codes, s_t, e2d, _maybe_key(key, cfg, 3),
            fmt=cfg.fmt, gs_fmt=cfg.gs_fmt, k_block=cfg.k_block,
            block_m=cfg.block_m, block_n=cfg.block_n,
            interpret=_interpret(cfg),
        )
    else:
        cols, _ = _im2col(x, ksize, stride, padding)
        dwmat = qd_gemm(
            cols.T, e2d, _maybe_key(key, cfg, 2), _maybe_key(key, cfg, 3),
            **kw,
        )  # (C*kh*kw, O)
    dw = dwmat.T.reshape(w.shape)
    # dA = qE @ qW^T: contraction over output channels, then col2im + STE
    dcols = qd_gemm(
        e2d, wmat, _maybe_key(key, cfg, 4), _maybe_key(key, cfg, 5), **kw
    )  # (N*OH*OW, C*kh*kw)
    dx = _col2im(dcols, x.shape, ksize, stride, padding, (n, oh, ow))
    return dx, dw


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def lowbit_conv_fused(x, w, key, stride, padding, cfg: QuantConfig):
    """NCHW conv running all three training GEMMs in the MLS quantized
    domain through the Pallas kernels (paper Alg. 1 on real arithmetic).

    ``x``: (N, C, H, W); ``w``: (O, C, kh, kw); ``stride`` a 2-tuple;
    ``padding`` "SAME"/"VALID" or explicit pairs.  Output is fp32
    (N, O, OH, OW).  Gradients follow Alg. 1 with STE: each backward GEMM
    re-quantizes its operands from float in its own contraction-aligned
    group layout.
    """
    return _conv_fwd_impl(x, w, key, stride, padding, cfg, PALLAS_BACKEND)


def _lcf_fwd(x, w, key, stride, padding, cfg: QuantConfig):
    y = _conv_fwd_impl(x, w, key, stride, padding, cfg, PALLAS_BACKEND)
    return y, (x, w, key)


def _lcf_bwd(stride, padding, cfg: QuantConfig, res, g):
    x, w, key = res
    dx, dw = _conv_bwd_impl(x, w, g, key, stride, padding, cfg, PALLAS_BACKEND)
    return dx.astype(x.dtype), dw.astype(w.dtype), None


lowbit_conv_fused.defvjp(_lcf_fwd, _lcf_bwd)


def lowbit_conv_fused_ref(x, w, key, stride, padding, cfg: QuantConfig):
    """jnp-oracle forward: same layout code, ref quantize/matmul."""
    return _conv_fwd_impl(x, w, key, stride, padding, cfg, REF_BACKEND)


def conv_fused_grads_ref(x, w, g, key, stride, padding, cfg: QuantConfig):
    """jnp-oracle (dx, dw) for cotangent ``g`` (bit-exactness tests)."""
    return _conv_bwd_impl(x, w, g, key, stride, padding, cfg, REF_BACKEND)


# ---------------------------------------------------------------------------
# Fused matmul with the same three-GEMM quantized-domain training semantics
# ---------------------------------------------------------------------------
def _mm_fwd_impl(x, w, key, cfg, backend):
    x2d = x.reshape(-1, x.shape[-1])
    y2d = qd_gemm(
        x2d, w.astype(jnp.float32),
        _maybe_key(key, cfg, 0), _maybe_key(key, cfg, 1),
        **_gemm_kwargs(cfg, backend),
    )
    return y2d.reshape(*x.shape[:-1], w.shape[1])


def _mm_bwd_impl(x, w, g, key, cfg, backend):
    x2d = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    e2d = g.reshape(-1, g.shape[-1]).astype(jnp.float32)
    kw = _gemm_kwargs(cfg, backend)
    # dX = qE @ qW^T (contraction over output features)
    dx2d = qd_gemm(
        e2d, w.astype(jnp.float32).T,
        _maybe_key(key, cfg, 2), _maybe_key(key, cfg, 3), **kw,
    )
    # dW = qX^T @ qE (contraction over rows)
    dw = qd_gemm(
        x2d.T, e2d, _maybe_key(key, cfg, 4), _maybe_key(key, cfg, 5), **kw
    )
    return dx2d.reshape(x.shape), dw


@partial(jax.custom_vjp, nondiff_argnums=(3,))
def lowbit_matmul_qd(x, w, key, cfg: QuantConfig):
    """``x (..., K) @ w (K, N)`` with all three training GEMMs in the MLS
    quantized domain (Pallas kernels) — the linear-layer analogue of
    :func:`lowbit_conv_fused`."""
    return _mm_fwd_impl(x, w, key, cfg, PALLAS_BACKEND)


def _lmq_fwd(x, w, key, cfg: QuantConfig):
    return _mm_fwd_impl(x, w, key, cfg, PALLAS_BACKEND), (x, w, key)


def _lmq_bwd(cfg: QuantConfig, res, g):
    x, w, key = res
    dx, dw = _mm_bwd_impl(x, w, g, key, cfg, PALLAS_BACKEND)
    return dx.astype(x.dtype), dw.astype(w.dtype), None


lowbit_matmul_qd.defvjp(_lmq_fwd, _lmq_bwd)


def matmul_qd_ref(x, w, key, cfg: QuantConfig):
    return _mm_fwd_impl(x, w, key, cfg, REF_BACKEND)


def matmul_qd_grads_ref(x, w, g, key, cfg: QuantConfig):
    return _mm_bwd_impl(x, w, g, key, cfg, REF_BACKEND)
