"""Shape-keyed Pallas kernel autotuner with a persistent tuning cache.

The paper's energy argument (Sec. VII) only materializes if the low-bit
GEMMs run at hardware speed, and no single static tiling does that across
shapes.  This module searches tiling candidates per *tuning key* —
``(kind, shape, <E,M> format, grouping)`` — and persists the winners:

* **Candidates** are :class:`BlockConfig` points — ``(block_m, block_n,
  k_block, grouping)`` for a GEMM, ``block_m`` for the quantizer,
  ``(impl, bh, block_n)`` for a conv — enumerated by
  :func:`gemm_candidates` / :func:`quantize_candidates` /
  :func:`conv_candidates`.
* **Pruning**: every candidate is first proven legal by the static verifier
  (:func:`repro.analysis.kernel_verify.verify_candidate`): grid coverage +
  the 2^24 integer-accumulation budget, from traced jaxpr metadata alone.
  Illegal tilings are never timed (and never cost a Mosaic compile).
* **Timing**: survivors run through the real fused pipeline
  (``lowbit_matmul_fused`` / ``mls_quantize_pallas``), best-of-n.
* **Persistence**: winners land in a JSON cache — ``.cache/kernel_tune.json``
  by default, overridable via the ``REPRO_TUNE_CACHE`` env var or an
  explicit path — merged over the committed seed cache
  (``kernels/tuned/kernel_tune.json``) that CI keeps fresh with
  ``python -m repro.kernels.autotune --check``.

Hot-path resolution (:func:`resolve_block_config`) never times or traces:
**explicit override > cache hit > proven-legal default**, where the default
is legal by construction (blocks are clamped and operands padded to block
multiples by the kernels; the accumulator budget is enforced at
``QuantConfig`` construction).

CLI::

    python -m repro.kernels.autotune --tune            # tune registry shapes
    python -m repro.kernels.autotune --check           # CI: seed cache fresh?
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import pathlib
import sys
import time
from collections.abc import Callable, Iterable

from repro.core.formats import EMFormat, accumulation_bits

__all__ = [
    "BlockConfig",
    "TuneSpec",
    "TuneCache",
    "CACHE_ENV_VAR",
    "CACHE_SCHEMA_VERSION",
    "DEFAULT_CACHE_PATH",
    "SEED_CACHE_PATH",
    "check_cache",
    "conv_candidates",
    "default_block_config",
    "gemm_candidates",
    "get_cache",
    "invalidate_cache",
    "quantize_candidates",
    "resolve_block_config",
    "time_config",
    "tune_all",
    "tune_spec",
]

CACHE_SCHEMA_VERSION = 1
CACHE_ENV_VAR = "REPRO_TUNE_CACHE"
DEFAULT_CACHE_PATH = pathlib.Path(".cache") / "kernel_tune.json"
SEED_CACHE_PATH = pathlib.Path(__file__).parent / "tuned" / "kernel_tune.json"

_MAX_ACC_BITS = 24  # fp32 integer-exactness budget (paper Sec. V-B)


# ---------------------------------------------------------------------------
# BlockConfig / TuneSpec
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class BlockConfig:
    """One tiling point of the Pallas kernel layer.

    ``block_m`` / ``block_n`` tile the GEMM output (``block_m`` doubles as
    the quantizer's row block); ``k_block`` is the contraction tile ==
    scaling-group width; ``grouping`` the group-scale layout the kernel
    executes (``kernels.mls_matmul.sg_shapes``).  For ``"conv"`` specs,
    ``impl`` selects the lowering (``"im2col"`` | ``"implicit"``); on the
    implicit kernel ``block_m`` stores ``bh`` (output rows per M-tile, the
    M-tile being ``bh*OW``).  Empty ``impl`` means "not a conv entry".
    """

    block_m: int
    block_n: int
    k_block: int
    grouping: str = "nc"
    impl: str = ""

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        if not self.impl:
            d.pop("impl")  # keep pre-conv cache entries byte-stable
        return d

    @classmethod
    def from_json(cls, d: dict) -> BlockConfig:
        return cls(
            block_m=int(d["block_m"]), block_n=int(d["block_n"]),
            k_block=int(d["k_block"]), grouping=str(d["grouping"]),
            impl=str(d.get("impl", "")),
        )

    def replace(self, **kw) -> BlockConfig:
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class TuneSpec:
    """One tunable workload: a GEMM or a quantizer call at a fixed shape.

    ``kind`` is ``"gemm"`` (shape ``(M, K, N)``), ``"quantize"``
    (shape ``(M, K)``) or ``"conv"`` (shape = the 13 geometry dims of
    ``implicit_conv.ConvGeom.as_dims()`` followed by ``k_block``, so the
    cache key distinguishes group widths).  ``k_block`` is the *caller's*
    group width — the gemm/quantize search may try neighbours, but conv
    candidates keep it fixed because it *is* the numerics (``cb*kh*kw``).
    """

    kind: str
    shape: tuple[int, ...]
    fmt: EMFormat
    k_block: int = 128
    grouping: str = "nc"

    def __post_init__(self):
        if self.kind not in ("gemm", "quantize", "conv"):
            raise ValueError(f"unknown TuneSpec kind {self.kind!r}")
        want = {"gemm": 3, "quantize": 2, "conv": 14}[self.kind]
        if len(self.shape) != want:
            raise ValueError(
                f"{self.kind} TuneSpec needs a rank-{want} shape, "
                f"got {self.shape}")
        if self.kind == "conv" and self.shape[13] != self.k_block:
            raise ValueError(
                "conv TuneSpec shape[13] must equal k_block, got "
                f"{self.shape[13]} != {self.k_block}")

    def key(self) -> str:
        """The cache key: (kind, shape, format, grouping)."""
        dims = "x".join(str(int(d)) for d in self.shape)
        return f"{self.kind}:{dims}:e{self.fmt.e}m{self.fmt.m}:{self.grouping}"

    def to_json(self) -> dict:
        return {
            "kind": self.kind, "shape": list(self.shape),
            "fmt": [self.fmt.e, self.fmt.m], "k_block": self.k_block,
            "grouping": self.grouping,
        }

    @classmethod
    def from_json(cls, d: dict) -> TuneSpec:
        e, m = d["fmt"]
        return cls(
            kind=str(d["kind"]), shape=tuple(int(s) for s in d["shape"]),
            fmt=EMFormat(int(e), int(m)), k_block=int(d.get("k_block", 128)),
            grouping=str(d.get("grouping", "nc")),
        )


def tune_spec(
    kind: str, shape: Iterable[int], fmt: EMFormat,
    k_block: int = 128, grouping: str = "nc",
) -> TuneSpec:
    return TuneSpec(kind, tuple(int(s) for s in shape), fmt, k_block, grouping)


def cache_key(
    kind: str, shape: Iterable[int], fmt: EMFormat, grouping: str
) -> str:
    dims = "x".join(str(int(d)) for d in shape)
    return f"{kind}:{dims}:e{fmt.e}m{fmt.m}:{grouping}"


# ---------------------------------------------------------------------------
# Persistent cache
# ---------------------------------------------------------------------------
class TuneCache:
    """JSON-backed map ``key -> (BlockConfig winner, timing metadata)``.

    Corrupted files and unknown schema versions degrade to an empty cache
    (recorded in ``load_warnings``) — resolution then falls back to the
    proven-legal defaults instead of crashing.
    """

    def __init__(self, path: str | os.PathLike | None = None):
        self.path = pathlib.Path(path) if path is not None else None
        self.entries: dict[str, dict] = {}
        self.load_warnings: list[str] = []

    # -- I/O ---------------------------------------------------------------
    @classmethod
    def load(cls, path: str | os.PathLike) -> TuneCache:
        cache = cls(path)
        p = pathlib.Path(path)
        if not p.exists():
            return cache
        try:
            payload = json.loads(p.read_text())
        except (json.JSONDecodeError, OSError, UnicodeDecodeError) as e:
            cache.load_warnings.append(f"{p}: unreadable tuning cache ({e})")
            return cache
        if not isinstance(payload, dict) or (
            payload.get("version") != CACHE_SCHEMA_VERSION
        ):
            cache.load_warnings.append(
                f"{p}: tuning-cache schema "
                f"{payload.get('version') if isinstance(payload, dict) else '?'}"
                f" != {CACHE_SCHEMA_VERSION}; ignoring stale cache"
            )
            return cache
        entries = payload.get("entries", {})
        if not isinstance(entries, dict):
            cache.load_warnings.append(f"{p}: malformed 'entries'; ignoring")
            return cache
        for key, ent in entries.items():
            try:
                BlockConfig.from_json(ent["config"])  # validate eagerly
                cache.entries[str(key)] = ent
            except (KeyError, TypeError, ValueError) as e:
                cache.load_warnings.append(
                    f"{p}: dropping malformed entry {key!r} ({e})")
        return cache

    def save(self, path: str | os.PathLike | None = None) -> pathlib.Path:
        p = pathlib.Path(path) if path is not None else self.path
        if p is None:
            raise ValueError("TuneCache.save: no path")
        p.parent.mkdir(parents=True, exist_ok=True)
        payload = {
            "version": CACHE_SCHEMA_VERSION,
            "generated_unix": round(time.time(), 1),
            "entries": {k: self.entries[k] for k in sorted(self.entries)},
        }
        p.write_text(json.dumps(payload, indent=2) + "\n")
        return p

    # -- access ------------------------------------------------------------
    def get(self, key: str) -> BlockConfig | None:
        ent = self.entries.get(key)
        return BlockConfig.from_json(ent["config"]) if ent else None

    def put(
        self, spec: TuneSpec, config: BlockConfig, us: float,
        timed: int = 0, source: str = "autotune",
    ) -> None:
        self.entries[spec.key()] = {
            **spec.to_json(),
            "config": config.to_json(),
            "us": round(float(us), 2),
            "candidates_timed": int(timed),
            "source": source,
        }

    def merged_over(self, base: TuneCache) -> TuneCache:
        """This cache's entries overlaid on ``base`` (self wins)."""
        out = TuneCache(self.path)
        out.entries = {**base.entries, **self.entries}
        out.load_warnings = base.load_warnings + self.load_warnings
        return out

    def __len__(self) -> int:
        return len(self.entries)


_CACHE: TuneCache | None = None


def get_cache() -> TuneCache:
    """The process-wide resolution cache, loaded once: the local cache
    (``REPRO_TUNE_CACHE`` env or ``.cache/kernel_tune.json``) merged over
    the committed seed cache."""
    global _CACHE
    if _CACHE is None:
        local = TuneCache.load(
            os.environ.get(CACHE_ENV_VAR, DEFAULT_CACHE_PATH))
        _CACHE = local.merged_over(TuneCache.load(SEED_CACHE_PATH))
    return _CACHE


def invalidate_cache() -> None:
    """Drop the memoized resolution cache (tests / after re-tuning)."""
    global _CACHE
    _CACHE = None


# ---------------------------------------------------------------------------
# Defaults and candidate enumeration
# ---------------------------------------------------------------------------
def default_block_config(
    spec: TuneSpec | None = None, *, shape: tuple[int, ...] | None = None,
    fmt: EMFormat | None = None, k_block: int = 128, grouping: str = "nc",
) -> BlockConfig:
    """The static tiling the kernels shipped with: 128^2 output tiles at
    the caller's ``k_block``.  Legal by construction — the kernels clamp
    blocks to the array extent and pad ragged tails, and the accumulator
    budget for ``(fmt, k_block)`` is enforced where the config is built."""
    if spec is not None:
        k_block, grouping = spec.k_block, spec.grouping
        if spec.kind == "conv":
            # im2col at the shipped GEMM tiles: always legal, any k_block.
            return BlockConfig(128, 128, k_block, grouping, impl="im2col")
    return BlockConfig(128, 128, k_block, grouping)


def _legal_k_blocks(fmt: EMFormat, k_block: int) -> list[int]:
    """The caller's group width plus power-of-two neighbours that keep the
    integer accumulator inside the fp32-exactness budget."""
    cands = {k_block, k_block // 2, k_block * 2, 64, 128}
    return sorted(
        kb for kb in cands
        if kb >= 16 and kb <= 512 and (kb & (kb - 1)) == 0
        and accumulation_bits(fmt, kb) < _MAX_ACC_BITS
    )


def gemm_candidates(spec: TuneSpec) -> list[BlockConfig]:
    """Candidate tilings for a GEMM spec, static default included (so the
    tuned winner can never lose to the shipped tiling)."""
    M, _, N = spec.shape
    bms = sorted({b for b in (32, 64, 128, 256) if b <= max(M, 128)})
    bns = sorted({b for b in (32, 64, 128, 256) if b <= max(N, 128)})
    out = [default_block_config(spec)]
    for kb in _legal_k_blocks(spec.fmt, spec.k_block):
        for bm in bms:
            for bn in bns:
                c = BlockConfig(bm, bn, kb, spec.grouping)
                if c not in out:
                    out.append(c)
    return out


def quantize_candidates(spec: TuneSpec) -> list[BlockConfig]:
    """Candidate row blocks for the quantizer (block_n unused, kept at the
    default for a well-formed BlockConfig)."""
    M, _ = spec.shape
    bms = sorted({b for b in (64, 128, 256, 512) if b <= max(M, 128)})
    out = [BlockConfig(256, 128, spec.k_block, spec.grouping)]  # shipped
    for bm in bms:
        c = BlockConfig(bm, 128, spec.k_block, spec.grouping)
        if c not in out:
            out.append(c)
    return out


def conv_candidates(spec: TuneSpec) -> list[BlockConfig]:
    """Candidate conv lowerings: the im2col default plus implicit-GEMM
    tilings when the layout is legal for ``spec.k_block``.

    Unlike the GEMM search, ``k_block`` is held fixed — for convs it *is*
    the scaling-group width (``cb * kh * kw``), i.e. the numerics.  For
    implicit candidates ``block_m`` stores ``bh`` (output rows per M-tile).
    """
    from .implicit_conv import ConvGeom, implicit_compatible

    geom = ConvGeom(*spec.shape[:13])
    out = [default_block_config(spec)]
    ok, _ = implicit_compatible(geom, spec.k_block)
    if not ok:
        return out
    bhs = [b for b in range(1, geom.oh + 1)
           if geom.oh % b == 0 and b * geom.ow <= 512]
    bns = sorted({b for b in (32, 64, 128) if b <= max(geom.o, 32)})
    for bh in bhs[-4:]:  # largest few row-tiles; tiny bh just adds grid steps
        for bn in bns:
            c = BlockConfig(bh, bn, spec.k_block, spec.grouping,
                            impl="implicit")
            if c not in out:
                out.append(c)
    return out


def candidates_for(spec: TuneSpec) -> list[BlockConfig]:
    if spec.kind == "gemm":
        return gemm_candidates(spec)
    if spec.kind == "conv":
        return conv_candidates(spec)
    return quantize_candidates(spec)


# ---------------------------------------------------------------------------
# Legality oracle (static verifier) and timing
# ---------------------------------------------------------------------------
def verify_config(spec: TuneSpec, config: BlockConfig):
    """Statically prove one candidate (grid coverage + accumulator budget)
    without compiling or executing — the autotuner's pruning step.  Returns
    the verifier's ``KernelReport``."""
    from repro.analysis.kernel_verify import (
        verify_candidate, verify_quantize_candidate)

    if spec.kind == "gemm":
        M, K, N = spec.shape
        return verify_candidate(
            (M, K, N), (spec.fmt, config.k_block),
            (config.block_m, config.block_n), grouping=config.grouping,
        )
    if spec.kind == "conv":
        from repro.analysis.kernel_verify import verify_implicit_conv_candidate
        from .implicit_conv import ConvGeom

        geom = ConvGeom(*spec.shape[:13])
        if config.impl == "implicit":
            return verify_implicit_conv_candidate(
                geom, spec.fmt, config.k_block, config.block_m,
                config.block_n, grouping=config.grouping,
            )
        # im2col lowers to the virtual GEMM — prove that.
        return verify_candidate(
            (geom.m0, geom.k0, geom.o), (spec.fmt, config.k_block),
            (config.block_m, config.block_n), grouping=config.grouping,
        )
    M, K = spec.shape
    return verify_quantize_candidate(
        (M, K), spec.fmt, config.k_block, config.block_m,
        grouping=config.grouping,
    )


def time_config(spec: TuneSpec, config: BlockConfig, n: int = 3) -> float:
    """Best-of-n wall time (us) of the fused pipeline at one tiling."""
    import jax
    import jax.numpy as jnp

    if spec.kind == "gemm":
        from .ops import lowbit_matmul_fused

        M, K, N = spec.shape
        x = jax.random.normal(jax.random.key(0), (M, K), jnp.float32)
        w = jax.random.normal(jax.random.key(1), (K, N), jnp.float32) * 0.1

        def fn():
            return lowbit_matmul_fused(
                x, w, None, fmt=spec.fmt, k_block=config.k_block,
                block_m=config.block_m, block_n=config.block_n,
                grouping=config.grouping,
            )
    elif spec.kind == "conv":
        from repro.core.lowbit import QuantConfig
        from .implicit_conv import ConvGeom
        from .lowbit_conv import lowbit_conv_fused

        geom = ConvGeom(*spec.shape[:13])
        x = jax.random.normal(
            jax.random.key(0), (geom.n, geom.c, geom.h, geom.w), jnp.float32)
        w = jax.random.normal(
            jax.random.key(1), (geom.o, geom.c, geom.kh, geom.kw),
            jnp.float32) * 0.1
        implicit = config.impl == "implicit"
        cfg = QuantConfig(
            fmt=spec.fmt, k_block=config.k_block, grouping=config.grouping,
            stochastic=False, backend="pallas",
            conv_impl="implicit" if implicit else "im2col",
            # conv BlockConfigs store bh in block_m; the QuantConfig wants
            # the M-tile in GEMM rows (bh * OW) on the implicit path.
            block_m=config.block_m * geom.ow if implicit else config.block_m,
            block_n=config.block_n,
        )
        stride = (geom.sh, geom.sw)
        padding = [(geom.ph_lo, geom.ph_hi), (geom.pw_lo, geom.pw_hi)]

        f = jax.jit(lambda a, b: lowbit_conv_fused(
            a, b, None, stride=stride, padding=padding, cfg=cfg))

        def fn():
            return f(x, w)
    else:
        from .mls_quantize import mls_quantize_pallas

        M, K = spec.shape
        x = jax.random.normal(jax.random.key(0), (M, K), jnp.float32)

        # the operand must be a real jit argument, not a closure constant —
        # XLA would constant-fold the whole quantization otherwise
        f = jax.jit(lambda a: mls_quantize_pallas(
            a, spec.fmt, config.k_block, block_m=config.block_m,
            grouping=config.grouping,
        ))

        def fn():
            return f(x)

    jax.block_until_ready(fn())  # compile
    best = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


# ---------------------------------------------------------------------------
# Tuning
# ---------------------------------------------------------------------------
def tune(
    spec: TuneSpec,
    cache: TuneCache,
    timer: Callable[[TuneSpec, BlockConfig], float] | None = None,
    force: bool = False,
    log: Callable[[str], None] | None = None,
) -> BlockConfig:
    """Tune one spec: cache hit short-circuits (no timing), otherwise
    enumerate -> prune with the static verifier -> time survivors -> persist
    the winner.  ``timer`` is injectable for tests."""
    key = spec.key()
    if not force:
        hit = cache.get(key)
        if hit is not None:
            return hit
    timer = timer or time_config
    say = log or (lambda _m: None)
    timed = 0
    best: tuple[float, BlockConfig] | None = None
    for config in candidates_for(spec):
        report = verify_config(spec, config)
        if not report.ok:
            say(f"  pruned {config} ({report.violations[0].kind})")
            continue
        us = timer(spec, config)
        timed += 1
        say(f"  {config}: {us:.1f} us")
        if best is None or us < best[0]:
            best = (us, config)
    if best is None:  # cannot happen: the static default always proves
        raise RuntimeError(f"no legal candidate for {key}")
    cache.put(spec, best[1], best[0], timed=timed)
    return best[1]


def registry_specs() -> list[TuneSpec]:
    """The tuning specs declared by ``KERNEL_REGISTRY`` entries."""
    from repro.kernels.registry import KERNEL_REGISTRY

    return [e.tune for e in KERNEL_REGISTRY.values() if e.tune is not None]


def tune_all(
    cache: TuneCache,
    specs: Iterable[TuneSpec] | None = None,
    timer: Callable[[TuneSpec, BlockConfig], float] | None = None,
    force: bool = False,
    log: Callable[[str], None] | None = None,
) -> dict[str, BlockConfig]:
    say = log or (lambda _m: None)
    out = {}
    for spec in specs if specs is not None else registry_specs():
        say(f"tuning {spec.key()}")
        out[spec.key()] = tune(spec, cache, timer=timer, force=force, log=log)
    return out


# ---------------------------------------------------------------------------
# Staleness check (CI --check mode; also the audit's cache gate)
# ---------------------------------------------------------------------------
def check_cache(
    cache: TuneCache, specs: Iterable[TuneSpec] | None = None,
) -> dict:
    """Prove the cache is fresh: every registry spec has an entry, and
    every cached winner still passes the static verifier.  Returns a
    report dict with ``ok`` and per-problem ``failures``."""
    failures: list[str] = []
    specs = list(specs) if specs is not None else registry_specs()
    for spec in specs:
        if cache.get(spec.key()) is None:
            failures.append(
                f"registry shape {spec.key()} has no tuning-cache entry "
                f"(run: python -m repro.kernels.autotune --tune)"
            )
    checked = 0
    for key, ent in sorted(cache.entries.items()):
        try:
            spec = TuneSpec.from_json(ent)
            config = BlockConfig.from_json(ent["config"])
        except (KeyError, TypeError, ValueError) as e:
            failures.append(f"cache entry {key}: malformed ({e})")
            continue
        report = verify_config(spec, config)
        checked += 1
        if not report.ok:
            v = report.violations[0]
            failures.append(
                f"cache entry {key}: winner {config} no longer verifies "
                f"({v.kind} at {v.where}: {v.detail})"
            )
    return {
        "ok": not failures,
        "entries": len(cache),
        "verified": checked,
        "required_specs": [s.key() for s in specs],
        "load_warnings": cache.load_warnings,
        "failures": failures,
    }


# ---------------------------------------------------------------------------
# Hot-path resolution: explicit override > cache hit > proven-legal default
# ---------------------------------------------------------------------------
def resolve_block_config(
    kind: str,
    shape: tuple[int, ...],
    fmt: EMFormat,
    grouping: str = "nc",
    *,
    k_block: int | None = None,
    block_m: int | None = None,
    block_n: int | None = None,
    cache: TuneCache | None = None,
) -> BlockConfig:
    """Resolve the tiling for one kernel call — pure lookup, never times.

    Field-level precedence: an explicit non-``None`` ``k_block`` /
    ``block_m`` / ``block_n`` overrides the cached winner, which overrides
    the static default.  ``k_block`` in particular is *numerics* (the
    scaling-group width), so callers that pin it keep their quantization
    semantics even when the cache's winner searched a different width.
    """
    cache = cache if cache is not None else get_cache()
    config = cache.get(cache_key(kind, shape, fmt, grouping))
    if config is None:
        config = default_block_config(
            shape=shape, fmt=fmt,
            k_block=k_block if k_block is not None else 128,
            grouping=grouping,
        )
    over = {}
    if k_block is not None and k_block != config.k_block:
        over["k_block"] = k_block
    if block_m is not None:
        over["block_m"] = block_m
    if block_n is not None:
        over["block_n"] = block_n
    return config.replace(**over) if over else config


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------
def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.kernels.autotune", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    ap.add_argument("--tune", action="store_true",
                    help="search + time the registry shapes, persist winners")
    ap.add_argument("--check", action="store_true",
                    help="CI mode: every registry shape cached and every "
                         "cached winner still proves legal; exit 1 otherwise")
    ap.add_argument("--cache", default=None, metavar="PATH",
                    help="cache file (default: committed seed for --check; "
                         f"$%s or %s for --tune)" % (
                             CACHE_ENV_VAR, DEFAULT_CACHE_PATH))
    ap.add_argument("--force", action="store_true",
                    help="re-time even on a cache hit")
    args = ap.parse_args(argv)
    if not (args.tune or args.check):
        ap.error("pick --tune and/or --check")

    rc = 0
    if args.tune:
        path = args.cache or os.environ.get(CACHE_ENV_VAR, DEFAULT_CACHE_PATH)
        cache = TuneCache.load(path)
        for w in cache.load_warnings:
            print(f"warning: {w}", file=sys.stderr)
        winners = tune_all(cache, force=args.force, log=print)
        out = cache.save(path)
        print(f"tuned {len(winners)} shape(s) -> {out}")

    if args.check:
        path = args.cache or SEED_CACHE_PATH
        cache = TuneCache.load(path)
        report = check_cache(cache)
        for w in report["load_warnings"]:
            print(f"warning: {w}", file=sys.stderr)
        print(f"checked {report['verified']} cached winner(s) in {path}; "
              f"{len(report['required_specs'])} registry spec(s) required")
        if not report["ok"]:
            print("TUNING-CACHE CHECK FAILURES:", file=sys.stderr)
            for f in report["failures"]:
                print(f"  - {f}", file=sys.stderr)
            rc = 1
        else:
            print("tuning cache: OK")
    return rc


if __name__ == "__main__":
    sys.exit(main())
