"""Process-wide Pallas execution-mode switch.

Every Pallas entry point used to carry its own ``interpret: bool = True``
default, so flipping a TPU run to compiled Mosaic meant editing call sites.
Now all of them default to ``interpret=None`` and resolve through
:func:`resolve_interpret` — one place, one precedence order:

1. an explicit ``interpret=`` argument (or ``QuantConfig.pallas_interpret``)
   always wins;
2. the ``REPRO_PALLAS_INTERPRET`` environment variable, when set
   (``0``/``false``/``no``/``off`` → Mosaic, anything else → interpreter);
3. platform auto-detection: the interpreter everywhere except a real TPU
   backend (interpret mode is the bit-exact default for CPU tests/CI;
   Mosaic is only meaningful — and only correct to default to — on TPU).
"""
from __future__ import annotations

import os

import jax

__all__ = ["INTERPRET_ENV_VAR", "default_interpret", "resolve_interpret"]

INTERPRET_ENV_VAR = "REPRO_PALLAS_INTERPRET"

_FALSY = ("0", "false", "no", "off")


def default_interpret() -> bool:
    """Interpret mode when no explicit argument is given (env > platform)."""
    v = os.environ.get(INTERPRET_ENV_VAR)
    if v is not None:
        return v.strip().lower() not in _FALSY
    return jax.default_backend() != "tpu"


def resolve_interpret(explicit: bool | None) -> bool:
    """Resolve a per-call ``interpret`` argument (explicit > env > auto)."""
    if explicit is not None:
        return bool(explicit)
    return default_interpret()
