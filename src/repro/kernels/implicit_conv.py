"""Implicit-GEMM fused conv kernel: quantize-in-prologue, no im2col.

The im2col lowering in :mod:`repro.kernels.lowbit_conv` materializes the
full fp32 patch matrix in HBM — every input element is duplicated
``kh * kw`` times at 4 bytes — before the quantize kernel even runs.  This
module is the paper's memory story done properly (Sec. VII: the energy win
of low-bit training is realized in *traffic*, not just MAC width): one
Pallas kernel walks the NCHW activation directly and fuses the dynamic
quantization of paper Alg. 2 into the GEMM prologue.

How the implicit GEMM is laid out
---------------------------------

The virtual GEMM is the same one im2col produces — ``(M0, K0) @ (K0, O)``
with ``M0 = N*OH*OW`` patch rows and ``K0 = C*kh*kw`` features in
``(c, kh, kw)`` order — but no patch matrix ever exists:

* Grid ``(M0/bm, Op/bn, K0/kb)`` with the contraction innermost, where the
  M-tile is ``bm = bh * OW`` (``bh`` whole output rows, ``bh | OH``) and the
  K-tile is ``kb = cb * kh * kw`` (``cb`` whole input channels, ``cb | C``).
  Tiles therefore never straddle an image, an output row, or a channel's
  taps, so no M/K padding exists and scaling groups are exactly whole
  channels' taps — the conv analogue of the paper's (n, c) grouping.
* The activation arrives spatially pre-padded as full-image blocks
  ``(1, C, Hp, Wp)`` whose index map depends only on the image index
  ``i // (OH/bh)``: consecutive grid steps (all j, k, and same-image row
  tiles) keep the same block index, so Pallas fetches each image from HBM
  **once** — the "activations read once" property the ROADMAP asks for.
* Inside the kernel, a program decodes its ``(i, k)`` grid coordinates into
  an ``(n, c0, h-band)`` window: it loads the ``band_h = sh*(bh-1)+kh`` halo
  band of rows its output rows need, gathers the ``kh*kw`` tap planes with
  static strided slices, and transposes them into the ``(bm, kb)`` GEMM
  tile.
* The quantize prologue then runs paper Alg. 2 **in VMEM** on that tile —
  in-kernel group maxima for ``"nc"``, precomputed compact scales for
  ``"c"``/``"n"``/``"none"`` — reusing the exact helpers of
  :mod:`repro.kernels.mls_quantize`, so codes and scales are bit-identical
  to the im2col pipeline with ``k_block = kb``.  Neither fp32 patches nor
  intermediate codes ever round-trip through HBM.
* The epilogue is :mod:`repro.kernels.mls_matmul`'s: decode to integer
  fractions, MXU dot (exact fp32 integer MACs, < 2^24), inter-group scale
  ``s_g^x * s_g^w``, and a final ``s_t^x * s_t^w * unit`` on the output
  tile.

Legality: the layout requires ``k_block = cb * kh * kw`` with ``cb | C``
(:func:`implicit_compatible`).  Incompatible configs keep the im2col path —
impl selection never changes quantization semantics.  Only the tensor/group
scales (cheap XLA reductions over the padded activation, no patch
materialization) and the optional stochastic-rounding draws are computed
outside the kernel.

Stochastic rounding uses the same u8 source as the im2col path, drawn over
the un-padded virtual GEMM shape ``(M0, K0)``; draws agree bit-for-bit with
the im2col/ref pipeline whenever that pipeline's tiles divide (M0, K0) —
the bit-exactness tests pin blocks accordingly.
"""
from __future__ import annotations

import dataclasses
import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.formats import EMFormat, GS_FMT_DEFAULT
from repro.core.quantize import quantize_group_scale
from .mls_matmul import _decode_frac, _sg_specs, sg_shapes
from .mls_quantize import GROUPINGS, _element_codes, _quantize_block
from .runtime import resolve_interpret

__all__ = [
    "CONV_IMPL_ENV_VAR",
    "CONV_IMPLS",
    "ConvGeom",
    "conv_geometry",
    "default_conv_blocks",
    "elementwise_codes",
    "im2col_conv_bytes",
    "implicit_compatible",
    "implicit_conv_bytes",
    "implicit_conv_forward",
    "patches_u8",
    "resolve_conv_blocks",
    "resolve_conv_impl",
]

CONV_IMPL_ENV_VAR = "REPRO_CONV_IMPL"
CONV_IMPLS = ("auto", "im2col", "implicit")

# Soft cap on the M-tile: bh is the largest divisor of OH with bh*OW under
# this (one full output row minimum), mirroring the GEMM default tiles.
_DEFAULT_BM_CAP = 256
_DEFAULT_BLOCK_N = 128


# ---------------------------------------------------------------------------
# Geometry
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ConvGeom:
    """Static NCHW conv geometry with normalized explicit padding."""

    n: int
    c: int
    h: int
    w: int
    o: int
    kh: int
    kw: int
    sh: int
    sw: int
    ph_lo: int
    ph_hi: int
    pw_lo: int
    pw_hi: int

    @property
    def hp(self) -> int:
        return self.h + self.ph_lo + self.ph_hi

    @property
    def wp(self) -> int:
        return self.w + self.pw_lo + self.pw_hi

    @property
    def oh(self) -> int:
        return (self.hp - self.kh) // self.sh + 1

    @property
    def ow(self) -> int:
        return (self.wp - self.kw) // self.sw + 1

    @property
    def kk(self) -> int:
        return self.kh * self.kw

    @property
    def m0(self) -> int:
        return self.n * self.oh * self.ow

    @property
    def k0(self) -> int:
        return self.c * self.kk

    def as_dims(self) -> tuple[int, ...]:
        """13-int canonical tuple (the conv TuneSpec shape, sans k_block)."""
        return (
            self.n, self.c, self.h, self.w, self.o, self.kh, self.kw,
            self.sh, self.sw, self.ph_lo, self.ph_hi, self.pw_lo, self.pw_hi,
        )


def conv_geometry(x_shape, w_shape, stride, padding) -> ConvGeom:
    """Normalize ``(x, w, stride, padding)`` into a :class:`ConvGeom`.

    ``padding`` accepts "SAME"/"VALID" or explicit ``[(lo, hi), (lo, hi)]``
    pairs — resolved with the same ``lax.padtype_to_pads`` rule the conv
    lowering uses, so geometry here matches ``conv_general_dilated_patches``
    exactly.
    """
    n, c, h, w = (int(d) for d in x_shape)
    o, c2, kh, kw = (int(d) for d in w_shape)
    assert c == c2, (x_shape, w_shape)
    sh, sw = (int(s) for s in stride)
    if isinstance(padding, str):
        pads = jax.lax.padtype_to_pads((h, w), (kh, kw), (sh, sw), padding)
    else:
        pads = padding
    # padtype_to_pads yields np.int64; pallas treats non-int grid dims as
    # *dynamic* grid bounds, so everything must be a Python int
    (ph_lo, ph_hi), (pw_lo, pw_hi) = [
        (int(lo), int(hi)) for lo, hi in pads]
    return ConvGeom(n, c, h, w, o, kh, kw, sh, sw, ph_lo, ph_hi, pw_lo, pw_hi)


def implicit_compatible(geom: ConvGeom, k_block: int) -> tuple[bool, str]:
    """Can the implicit layout realize ``k_block``-wide scaling groups?

    Groups must be whole channels' taps: ``k_block = cb * kh * kw`` with
    ``cb | C``.  Returns ``(ok, reason)`` — the reason names the nearest
    legal k_block when not.
    """
    kk = geom.kk
    if geom.oh < 1 or geom.ow < 1:
        return False, "empty output window"
    if k_block % kk:
        legal = _nearest_conv_k_block(geom, k_block)
        return False, (
            f"k_block={k_block} is not a multiple of kh*kw={kk} "
            f"(nearest legal: {legal})"
        )
    cb = k_block // kk
    if cb < 1 or geom.c % cb:
        legal = _nearest_conv_k_block(geom, k_block)
        return False, (
            f"k_block={k_block} needs cb={cb} whole channels per group but "
            f"cb does not divide C={geom.c} (nearest legal: {legal})"
        )
    return True, ""


def _nearest_conv_k_block(geom: ConvGeom, k_block: int) -> int:
    """Largest legal conv k_block (= cb*kh*kw, cb | C) not above k_block."""
    best = geom.kk
    for cb in range(1, geom.c + 1):
        if geom.c % cb == 0 and cb * geom.kk <= max(k_block, geom.kk):
            best = cb * geom.kk
    return best


def default_conv_blocks(geom: ConvGeom) -> tuple[int, int]:
    """Proven-legal default ``(bh, block_n)``: the largest divisor of OH
    whose M-tile ``bh*OW`` stays under the default cap, and the GEMM's
    default N-tile."""
    bh = 1
    for cand in range(1, geom.oh + 1):
        if geom.oh % cand == 0 and cand * geom.ow <= _DEFAULT_BM_CAP:
            bh = cand
    return bh, min(_DEFAULT_BLOCK_N, max(geom.o, 1))


# ---------------------------------------------------------------------------
# Impl/block resolution: explicit > env > tuned cache > legality default
# ---------------------------------------------------------------------------
def conv_tune_dims(geom: ConvGeom, k_block: int) -> tuple[int, ...]:
    """Conv TuneSpec shape: geometry + k_block (k_block is numerics-bearing
    for convs — the grouping width — so it keys the cache entry)."""
    return (*geom.as_dims(), k_block)


def _cached_conv_config(geom: ConvGeom, fmt, grouping: str, k_block: int):
    from .autotune import TuneSpec, get_cache  # lazy: avoids an import cycle

    spec = TuneSpec("conv", conv_tune_dims(geom, k_block), fmt,
                    k_block=k_block, grouping=grouping)
    return get_cache().get(spec.key())


def resolve_conv_impl(geom: ConvGeom, cfg) -> str:
    """Pick ``"im2col"`` or ``"implicit"`` for this conv.

    Precedence: ``REPRO_CONV_IMPL`` env (A/B runs) > ``cfg.conv_impl`` >
    tuned-cache winner > implicit-when-legal default.  An explicit
    ``"implicit"`` request on an incompatible ``k_block`` raises — impl
    selection never silently changes the scaling-group semantics.
    """
    env = os.environ.get(CONV_IMPL_ENV_VAR, "").strip().lower()
    if env and env not in CONV_IMPLS:
        raise ValueError(
            f"{CONV_IMPL_ENV_VAR}={env!r}: expected one of {CONV_IMPLS}")
    choice = env or getattr(cfg, "conv_impl", "auto")
    ok, reason = implicit_compatible(geom, cfg.k_block)
    if choice == "im2col":
        return "im2col"
    if choice == "implicit":
        if not ok:
            raise ValueError(
                f"conv_impl='implicit' is not legal for this conv: {reason}")
        return "implicit"
    # "auto"
    if not ok:
        return "im2col"
    cached = _cached_conv_config(geom, cfg.fmt, cfg.grouping, cfg.k_block)
    if cached is not None and getattr(cached, "impl", ""):
        return cached.impl
    return "implicit"


def resolve_conv_blocks(
    geom: ConvGeom, cfg, *, block_m: int | None = None,
    block_n: int | None = None,
) -> tuple[int, int]:
    """Resolve the implicit kernel's ``(bh, block_n)``.

    ``cfg.block_m`` (if set) is the M-tile in GEMM rows and must be a
    ``bh * OW`` multiple of whole output rows; ``cfg.block_n`` is the
    output-channel tile.  Unset fields resolve through the tuned cache
    (``BlockConfig.block_m`` stores ``bh`` for conv entries), then the
    legality default.
    """
    block_m = cfg.block_m if block_m is None else block_m
    block_n = cfg.block_n if block_n is None else block_n
    bh_default, bn_default = default_conv_blocks(geom)
    bh = bn = None
    if block_m is not None:
        if block_m % geom.ow or geom.oh % (block_m // geom.ow):
            raise ValueError(
                f"implicit conv block_m={block_m} must be bh*OW with bh "
                f"dividing OH (OW={geom.ow}, OH={geom.oh})")
        bh = block_m // geom.ow
    if block_n is not None:
        bn = block_n
    if bh is None or bn is None:
        cached = _cached_conv_config(geom, cfg.fmt, cfg.grouping, cfg.k_block)
        if cached is not None and getattr(cached, "impl", "") == "implicit":
            if bh is None and geom.oh % max(cached.block_m, 1) == 0:
                bh = cached.block_m
            if bn is None:
                bn = cached.block_n
    return bh if bh is not None else bh_default, \
        bn if bn is not None else bn_default


# ---------------------------------------------------------------------------
# Scale precompute (exact, window-based — no patch materialization)
# ---------------------------------------------------------------------------
def _covered_abs_max(xp: jax.Array, geom: ConvGeom) -> jax.Array:
    """Per-(n, c, patch) abs-max over each conv window — (N, C, OH, OW).

    Only pixels some patch actually covers contribute (VALID/stride can
    leave a tail uncovered), matching ``max|im2col(x)|`` exactly.
    """
    return jax.lax.reduce_window(
        jnp.abs(xp), -jnp.inf, jax.lax.max,
        (1, 1, geom.kh, geom.kw), (1, 1, geom.sh, geom.sw), "VALID",
    )


def _tap_abs_max(xp: jax.Array, geom: ConvGeom) -> jax.Array:
    """Per-feature abs-max over all patches — (C*kh*kw,) in (c, kh, kw)
    order, i.e. ``max|im2col(x)|`` along the patch axis."""
    a = jnp.abs(xp)
    cols = []
    for kh_ in range(geom.kh):
        for kw_ in range(geom.kw):
            sl = a[
                :, :,
                kh_: kh_ + 1 + geom.sh * (geom.oh - 1): geom.sh,
                kw_: kw_ + 1 + geom.sw * (geom.ow - 1): geom.sw,
            ]
            cols.append(sl.max(axis=(0, 2, 3)))  # (C,)
    return jnp.stack(cols, axis=1).reshape(-1)  # (C, KK) -> (C*KK,)


def _implicit_x_scales(xp, geom: ConvGeom, fmt, gs_fmt, kb, grouping):
    """(s_t, compact s_g | None) for the activation, bit-identical to the
    im2col pipeline's (``quantize_ref`` / ``mls_quantize_pallas``) scales.

    ``"nc"`` group scales are computed inside the kernel (groups live in one
    tile); ``"n"``/``"c"`` cross k-tiles / row-tiles in the implicit layout
    so their compact scales are precomputed here with the exact
    ``quantize_group_scale`` math the reference uses.
    """
    n_kb = geom.k0 // kb
    if grouping in ("c", "none"):
        feat = _tap_abs_max(xp, geom)  # (K0,)
        s_t = jnp.max(feat)
    else:
        win = _covered_abs_max(xp, geom)  # (N, C, OH, OW)
        s_t = jnp.max(win)
    s_t = jnp.where(s_t > 0, s_t, 1.0)
    if grouping == "nc":
        return s_t, None
    if grouping == "n":
        s_r = win.max(axis=1).reshape(geom.m0, 1)  # per-row (patch) max
        s_g, _, _ = quantize_group_scale(s_r / s_t, gs_fmt)
        return s_t, s_g  # (M0, 1)
    if grouping == "c":
        s_r = feat.reshape(n_kb, kb).max(axis=1)[None, :]  # (1, n_kb)
        s_g, _, _ = quantize_group_scale(s_r / s_t, gs_fmt)
        return s_t, s_g
    return s_t, jnp.ones((1, 1), jnp.float32)  # "none"


# ---------------------------------------------------------------------------
# The fused kernel
# ---------------------------------------------------------------------------
def _gather_tile(x_ref, geom: ConvGeom, bh: int, cb: int, bm: int, kb: int):
    """Decode (i, k) grid coords into the (bm, kb) implicit-GEMM tile.

    Loads the halo band of input rows this program's output rows touch,
    then gathers the kh*kw tap planes with static strided slices — the
    "index map" of the implicit GEMM, executed on VMEM-resident data.
    """
    i = pl.program_id(0)
    k = pl.program_id(2)
    oh_tiles = geom.oh // bh
    band_h = geom.sh * (bh - 1) + geom.kh
    row0 = (i % oh_tiles) * bh * geom.sh
    c0 = k * cb
    band = pl.load(
        x_ref,
        (pl.dslice(0, 1), pl.dslice(c0, cb), pl.dslice(row0, band_h),
         pl.dslice(0, geom.wp)),
    )[0]  # (cb, band_h, Wp)
    taps = []
    for kh_ in range(geom.kh):
        for kw_ in range(geom.kw):
            taps.append(band[
                :,
                kh_: kh_ + 1 + geom.sh * (bh - 1): geom.sh,
                kw_: kw_ + 1 + geom.sw * (geom.ow - 1): geom.sw,
            ])  # (cb, bh, OW)
    g = jnp.stack(taps, axis=1)  # (cb, KK, bh, OW)
    # rows: (oh_local, ow) = patch order; cols: (c_local, kh, kw) = the
    # im2col feature order restricted to this k-block.
    return g.transpose(2, 3, 0, 1).reshape(bm, kb)


def _implicit_kernel(
    *refs, geom: ConvGeom, fmt: EMFormat, gs_fmt: EMFormat, grouping: str,
    stochastic: bool, emit: bool, bh: int, cb: int, n_k: int,
):
    bm, kb = bh * geom.ow, cb * geom.kk
    it = iter(refs)
    x_ref = next(it)
    r_ref = next(it) if stochastic else None
    stx_ref = next(it)
    stp_ref = next(it)
    xsg_ref = None if grouping == "nc" else next(it)
    wc_ref = next(it)
    wsg_ref = next(it)
    out_ref = next(it)
    codes_ref = next(it) if emit else None
    sgo_ref = next(it) if (emit and grouping == "nc") else None
    acc_ref = next(it)

    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = _gather_tile(x_ref, geom, bh, cb, bm, kb)
    r = r_ref[...] if stochastic else jnp.full((bm, kb), 127, jnp.uint8)

    # ---- quantize prologue: paper Alg. 2 on the VMEM tile ----------------
    if grouping == "nc":
        codes, s_g = _quantize_block(a, r, stx_ref[0, 0], fmt, gs_fmt)
        xs = s_g[:, None]  # (bm, 1): the matmul-side compact scale block
    else:
        xs = xsg_ref[...]  # (1,1) for "c"/"none", (bm,1) for "n"
        codes = _element_codes(a, r, stx_ref[0, 0] * xs, fmt)

    # ---- GEMM body: identical to mls_matmul's _kernel --------------------
    fx = _decode_frac(codes, fmt)
    fw = _decode_frac(wc_ref[...], fmt)
    p = jnp.dot(fx, fw, preferred_element_type=jnp.float32)
    sp = xs * wsg_ref[...]
    acc_ref[...] += p * sp

    @pl.when(k == n_k - 1)
    def _done():
        unit = 2.0 ** (2 * (fmt.e_min - fmt.m))
        out_ref[...] = acc_ref[...] * (stp_ref[0, 0] * unit)

    if emit:
        codes_ref[...] = codes
        if sgo_ref is not None:
            sgo_ref[...] = xs


def _xsg_spec(grouping: str, bm: int):
    """BlockSpec for the precomputed compact activation scales."""
    if grouping == "c":
        return pl.BlockSpec((1, 1), lambda i, j, k: (0, k))
    if grouping == "n":
        return pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0))
    return pl.BlockSpec((1, 1), lambda i, j, k: (0, 0))  # "none"


def implicit_conv_forward(
    x: jax.Array,
    w: jax.Array,
    key_x: jax.Array | None,
    key_w: jax.Array | None,
    stride,
    padding,
    *,
    fmt: EMFormat,
    gs_fmt: EMFormat = GS_FMT_DEFAULT,
    k_block: int,
    bh: int | None = None,
    block_n: int | None = None,
    grouping: str = "nc",
    interpret: bool | None = None,
    emit_codes: bool = False,
):
    """Fused implicit-GEMM forward conv: fp32 NCHW in, fp32 NCHW out.

    ``x`` (N, C, H, W), ``w`` (O, C, kh, kw).  ``k_block`` must satisfy
    :func:`implicit_compatible`.  With ``emit_codes=True`` also returns
    ``(codes (M0, K0), x_sg compact, s_t)`` — the activation's quantized
    form in im2col layout, for bit-exactness tests against the reference
    pipeline (the codes round-trip through HBM only in this debug mode).
    """
    if grouping not in GROUPINGS:
        raise ValueError(
            f"unknown grouping {grouping!r}; expected one of {GROUPINGS}")
    geom = conv_geometry(x.shape, w.shape, stride, padding)
    ok, reason = implicit_compatible(geom, k_block)
    if not ok:
        raise ValueError(f"implicit_conv_forward: {reason}")
    cb = k_block // geom.kk
    kb = k_block
    n_k = geom.k0 // kb
    if bh is None or block_n is None:
        bh_d, bn_d = default_conv_blocks(geom)
        bh = bh_d if bh is None else bh
        block_n = bn_d if block_n is None else block_n
    if geom.oh % bh:
        raise ValueError(
            f"implicit conv bh={bh} must divide OH={geom.oh}")
    bm = bh * geom.ow
    interpret = resolve_interpret(interpret)

    xp = jnp.pad(
        x.astype(jnp.float32),
        ((0, 0), (0, 0), (geom.ph_lo, geom.ph_hi), (geom.pw_lo, geom.pw_hi)),
    )
    s_t, x_sg = _implicit_x_scales(xp, geom, fmt, gs_fmt, kb, grouping)
    stx = s_t.reshape(1, 1)

    # Weight side: byte-for-byte the im2col pipeline's (see qd_gemm) — the
    # OIHW weight flattens to (K0, O), is padded to the N-tile, and is
    # quantized transposed so its groups run along the contraction.
    from .mls_quantize import mls_quantize_pallas  # local: keep import light

    # O pads to the *unclamped* block_n multiple — exactly qd_gemm's
    # _pad_to — so the weight-side stochastic draws are shape-identical to
    # the im2col/ref pipeline; the kernel's N-tile clamps separately below.
    wmat = w.reshape(geom.o, -1).T.astype(jnp.float32)  # (K0, O)
    pn = (-geom.o) % block_n
    wp = jnp.pad(wmat, ((0, 0), (0, pn))) if pn else wmat
    op = geom.o + pn
    bn = min(block_n, op)
    wc, wsgT, wst = mls_quantize_pallas(
        wp.T, fmt, kb, gs_fmt, key_w, block_m=block_n, interpret=interpret,
        grouping=grouping,
    )
    wcT, wsg = wc.T, wsgT.T
    stp = (s_t * wst).astype(jnp.float32).reshape(1, 1)

    stochastic = key_x is not None
    oh_tiles = geom.oh // bh
    grid = (geom.m0 // bm, op // bn, n_k)

    in_specs = [
        pl.BlockSpec((1, geom.c, geom.hp, geom.wp),
                     lambda i, j, k, t=oh_tiles: (i // t, 0, 0, 0)),
    ]
    operands = [xp]
    if stochastic:
        r_u8 = jax.random.randint(
            key_x, (geom.m0, geom.k0), 0, 256, dtype=jnp.int32
        ).astype(jnp.uint8)
        in_specs.append(pl.BlockSpec((bm, kb), lambda i, j, k: (i, k)))
        operands.append(r_u8)
    in_specs += [
        pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),  # stx
        pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),  # stp
    ]
    operands += [stx, stp]
    if grouping != "nc":
        in_specs.append(_xsg_spec(grouping, bm))
        operands.append(x_sg)
    wsg_spec = _sg_specs(grouping, bm, bn)[1]
    in_specs += [
        pl.BlockSpec((kb, bn), lambda i, j, k: (k, j)),
        wsg_spec,
    ]
    operands += [wcT, wsg]

    out_specs = [pl.BlockSpec((bm, bn), lambda i, j, k: (i, j))]
    out_shape = [jax.ShapeDtypeStruct((geom.m0, op), jnp.float32)]
    if emit_codes:
        out_specs.append(pl.BlockSpec((bm, kb), lambda i, j, k: (i, k)))
        out_shape.append(jax.ShapeDtypeStruct((geom.m0, geom.k0), jnp.uint8))
        if grouping == "nc":
            out_specs.append(pl.BlockSpec((bm, 1), lambda i, j, k: (i, k)))
            out_shape.append(
                jax.ShapeDtypeStruct((geom.m0, n_k), jnp.float32))

    kernel = functools.partial(
        _implicit_kernel, geom=geom, fmt=fmt, gs_fmt=gs_fmt,
        grouping=grouping, stochastic=stochastic, emit=emit_codes,
        bh=bh, cb=cb, n_k=n_k,
    )
    res = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs if emit_codes else out_specs[0],
        out_shape=out_shape if emit_codes else out_shape[0],
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(*operands)

    y2d = res[0] if emit_codes else res
    y = y2d[:, : geom.o].reshape(geom.n, geom.oh, geom.ow, geom.o)
    y = y.transpose(0, 3, 1, 2)
    if not emit_codes:
        return y
    codes = res[1]
    if grouping == "nc":
        x_sg = res[2]
    return y, codes, x_sg, s_t


# ---------------------------------------------------------------------------
# Forward-code reuse for the weight-grad GEMM ("none" grouping)
# ---------------------------------------------------------------------------
def elementwise_codes(v: jax.Array, s_t: jax.Array, fmt: EMFormat):
    """Deterministic element codes against a tensor-wide scale (the
    ``grouping="none"`` quantizer, r = 127) — exactly the forward kernel's
    prologue, so gathering these *is* reusing the forward codes."""
    r = jnp.full(v.shape, 127, jnp.uint8)
    return _element_codes(v.astype(jnp.float32), r, s_t, fmt)


def patches_u8(xq: jax.Array, geom: ConvGeom) -> jax.Array:
    """im2col gather on uint8 codes — (N, C, Hp, Wp) -> (M0, K0) in the
    (c, kh, kw) feature order (``conv_general_dilated_patches`` only takes
    floats; codes stay 1 byte/element through this gather)."""
    taps = []
    for kh_ in range(geom.kh):
        for kw_ in range(geom.kw):
            taps.append(xq[
                :, :,
                kh_: kh_ + 1 + geom.sh * (geom.oh - 1): geom.sh,
                kw_: kw_ + 1 + geom.sw * (geom.ow - 1): geom.sw,
            ])  # (N, C, OH, OW)
    g = jnp.stack(taps, axis=2)  # (N, C, KK, OH, OW)
    return g.transpose(0, 3, 4, 1, 2).reshape(geom.m0, geom.k0)


def covered_tensor_scale(x: jax.Array, geom: ConvGeom) -> jax.Array:
    """The forward tensor scale s_t: abs-max over covered (padded) pixels."""
    xp = jnp.pad(
        x.astype(jnp.float32),
        ((0, 0), (0, 0), (geom.ph_lo, geom.ph_hi), (geom.pw_lo, geom.pw_hi)),
    )
    s_t = jnp.max(_covered_abs_max(xp, geom))
    return jnp.where(s_t > 0, s_t, 1.0), xp


# ---------------------------------------------------------------------------
# Bytes-moved estimators (the interpret-mode stand-in for HBM counters)
# ---------------------------------------------------------------------------
def _ceil_to(v: int, m: int) -> int:
    return v + (-v) % m


def _gemm_code_traffic(M: int, K: int, N: int, bm: int, bn: int) -> int:
    """u8 code bytes the tiled GEMM fetches: each operand block is re-read
    once per sweep of the other operand's independent grid axis (Pallas
    only dedups *consecutive* grid steps with an unchanged block index)."""
    return M * K * (N // bn) + K * N * (M // bm)


def im2col_conv_bytes(
    geom: ConvGeom, k_block: int, *, block_m: int = 128, block_n: int = 128,
    stochastic: bool = False,
) -> dict:
    """HBM bytes-moved model of the im2col forward path.

    Counts: reading x, materializing + re-reading the fp32 patch matrix,
    writing/reading both operands' codes, the stochastic draws, and the
    fp32 output.  Scales are a few hundred bytes and are ignored on both
    paths.
    """
    mp = _ceil_to(geom.m0, min(block_m, geom.m0))
    kp = _ceil_to(geom.k0, k_block)
    np_ = _ceil_to(geom.o, min(block_n, max(geom.o, 1)))
    bm = min(block_m, geom.m0)
    bn = min(block_n, max(geom.o, 1))
    x_bytes = 4 * geom.n * geom.c * geom.h * geom.w
    cols = 4 * mp * kp
    w_io = 4 * kp * np_ + kp * np_  # fp32 read + code write
    quant_x = cols + mp * kp  # fp32 re-read + code write
    r_bytes = (mp * kp + kp * np_) if stochastic else 0
    gemm = _gemm_code_traffic(mp, kp, np_, bm, bn)
    out = 4 * mp * np_
    total = x_bytes + cols + quant_x + w_io + r_bytes + gemm + out
    return {
        "total": total, "x_read": x_bytes, "im2col_materialize": cols,
        "quantize": quant_x + w_io, "stochastic_draws": r_bytes,
        "gemm_codes": gemm, "out": out,
    }


def implicit_conv_bytes(
    geom: ConvGeom, k_block: int, *, bh: int | None = None,
    block_n: int | None = None, grouping: str = "nc",
    stochastic: bool = False,
) -> dict:
    """HBM bytes-moved model of the fused implicit path.

    The activation is written once spatially padded, re-read once by the
    scale precompute, and fetched into VMEM **once per image** by the
    kernel (the full-image block's index map only changes with the image
    index).  No patch matrix, no activation-code round-trip.
    """
    bh_d, bn_d = default_conv_blocks(geom)
    bh = bh_d if bh is None else bh
    bn = min(bn_d if block_n is None else block_n, max(geom.o, 1))
    bm = bh * geom.ow
    np_ = _ceil_to(geom.o, bn)
    xp_bytes = 4 * geom.n * geom.c * geom.hp * geom.wp
    x_io = 4 * geom.n * geom.c * geom.h * geom.w + xp_bytes  # read + pad write
    scale_pre = xp_bytes  # one fused reduction pass
    kernel_x = xp_bytes  # fetched once per image
    w_io = 4 * geom.k0 * np_ + geom.k0 * np_
    w_codes = geom.k0 * np_ * (geom.m0 // bm)
    r_bytes = (geom.m0 * geom.k0 + geom.k0 * np_) if stochastic else 0
    out = 4 * geom.m0 * np_
    total = x_io + scale_pre + kernel_x + w_io + w_codes + r_bytes + out
    return {
        "total": total, "x_read": x_io, "scale_precompute": scale_pre,
        "kernel_x_fetch": kernel_x, "quantize": w_io,
        "stochastic_draws": r_bytes, "gemm_codes": w_codes, "out": out,
    }
