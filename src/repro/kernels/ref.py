"""Pure-jnp oracles for the Pallas kernels.

These mirror the kernels' *quantized-domain* semantics exactly (integer
fractions, group scales, tensor scale factored out), so kernel-vs-ref tests
can assert bit-identical results, and they are cross-checked against the
float `repro.core` implementation in the test suite.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.core.formats import EMFormat, GS_FMT_DEFAULT
from repro.core.quantize import (
    GroupSpec,
    mls_quantize,
    pack_elements,
    unpack_elements,
)


def grouping_spec(grouping: str, k_block: int) -> GroupSpec:
    """GroupSpec of a 2-D (rows, contraction) operand for one grouping."""
    if grouping == "nc":
        return GroupSpec((1, k_block))
    if grouping == "c":
        return GroupSpec((None, k_block))
    if grouping == "n":
        return GroupSpec((1, None))
    if grouping == "none":
        return GroupSpec((None, None))
    raise ValueError(f"unknown grouping {grouping!r}")


def quantize_ref(
    x: jax.Array,
    fmt: EMFormat,
    k_block: int,
    gs_fmt: EMFormat = GS_FMT_DEFAULT,
    r_u8: jax.Array | None = None,
    grouping: str = "nc",
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Reference dynamic quantization of a 2-D operand ``(M, K)``.

    Scaling groups follow ``grouping`` (default ``"nc"``: one group per
    (row, k-block)).  ``r_u8`` is the uint8 stochastic-rounding source the
    kernel consumes (``None`` -> round-to-nearest).  Returns ``(codes_u8,
    s_g_f32, s_t_f32_scalar)`` with ``codes`` the packed sign/exp/man
    elements and ``s_g`` in the grouping's compact layout (``"nc"``:
    ``(M, K // k_block)``; see ``kernels.mls_matmul.sg_shapes``).
    """
    assert x.ndim == 2
    if grouping in ("nc", "c"):
        assert x.shape[1] % k_block == 0
    if r_u8 is not None:
        # mirror the kernel: u = (r + 0.5)/256 - 0.5 in (-0.5, 0.5)
        r = (r_u8.astype(jnp.float32) + 0.5) / 256.0 - 0.5
    else:
        r = None
    spec = grouping_spec(grouping, k_block)
    # re-implement mls_quantize but with the supplied rounding tensor
    from repro.core.quantize import (
        broadcast_groups,
        group_reduce_max,
        quantize_elements,
        quantize_group_scale,
    )

    xf32 = x.astype(jnp.float32)
    sign = jnp.sign(xf32).astype(jnp.int8)
    absx = jnp.abs(xf32)
    s_r = group_reduce_max(absx, spec)
    s_t = jnp.max(s_r)
    s_t = jnp.where(s_t > 0, s_t, 1.0)
    s_g, _, _ = quantize_group_scale(s_r / s_t, gs_fmt)
    denom = s_t * broadcast_groups(s_g, spec, x.shape)
    x_f = jnp.where(denom > 0, absx / jnp.where(denom > 0, denom, 1.0), 0.0)
    xbar, exp_x, man_x = quantize_elements(x_f, fmt, r)
    sign_bit = (sign.astype(jnp.int32) < 0).astype(jnp.int32)
    codes = ((sign_bit << (fmt.e + fmt.m)) | (exp_x << fmt.m) | man_x).astype(
        jnp.uint8
    )
    return codes, s_g, s_t


def decode_frac_int(codes: jax.Array, fmt: EMFormat) -> jax.Array:
    """uint8 codes -> signed integer fractions F (paper Eq. 7 operands).

    ``|value| = |F| * 2^(e_min - M)``; F fits in ``M + 2^E - 1`` magnitude
    bits plus sign.
    """
    c = codes.astype(jnp.int32)
    man = c & (2**fmt.m - 1)
    exp = (c >> fmt.m) & (2**fmt.e - 1)
    sign_bit = c >> (fmt.e + fmt.m)
    top = 2**fmt.e - 1
    is_denorm = exp == 0
    base = jnp.where(is_denorm, man, 2**fmt.m + man)
    shift = jnp.where(is_denorm, 0, top - exp)
    f = base << shift
    return jnp.where(sign_bit == 1, -f, f)


def mls_matmul_ref(
    x_codes: jax.Array,
    x_sg: jax.Array,
    x_st: jax.Array,
    w_codes: jax.Array,
    w_sg: jax.Array,
    w_st: jax.Array,
    fmt: EMFormat,
    k_block: int,
) -> jax.Array:
    """Quantized-domain GEMM oracle (paper Eq. 6-8).

    x: (M, K) codes;  w: (K, N) codes.  The group scales may arrive in any
    compact grouping layout (``sg_shapes``) — they are broadcast to the
    ``"nc"`` resolution (M, K/kb) / (K/kb, N), which subsumes the coarser
    layouts exactly.
    Intra-group: integer MAC over each k-block (exact in fp32).
    Inter-group: group-scale product (a shift-add in hardware, exact fp32
    multiply here) then fp32 accumulation — the paper's adder tree.
    """
    M, K = x_codes.shape
    K2, N = w_codes.shape
    assert K == K2 and K % k_block == 0
    nkb = K // k_block
    x_sg = jnp.broadcast_to(x_sg, (M, nkb))
    w_sg = jnp.broadcast_to(w_sg, (nkb, N))
    fx = decode_frac_int(x_codes, fmt).astype(jnp.float32)  # exact small ints
    fw = decode_frac_int(w_codes, fmt).astype(jnp.float32)
    fx = fx.reshape(M, nkb, k_block)
    fw = fw.reshape(nkb, k_block, N)
    # intra-group integer MACs: P[m, g, n]
    p = jnp.einsum("mgk,gkn->gmn", fx, fw)
    # inter-group: scale by S_p = s_g^x * s_g^w and accumulate
    sp = x_sg.T[:, :, None] * w_sg[:, None, :]  # (g, M, N)
    z = jnp.sum(p * sp, axis=0)
    unit = 2.0 ** (2 * (fmt.e_min - fmt.m))
    return z * (x_st * w_st * unit)
