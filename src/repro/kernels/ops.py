"""Public jit'd wrappers around the Pallas MLS kernels.

``lowbit_matmul_fused`` is the end-to-end quantized GEMM: both float
operands are dynamically quantized by the Pallas quantization kernel and
contracted by the quantized-domain Pallas GEMM.  Interpret mode resolves
through :mod:`repro.kernels.runtime` (explicit > ``REPRO_PALLAS_INTERPRET``
> platform auto), and tilings left at ``None`` resolve through the
autotuner cache (explicit override > cache hit > proven-legal default; see
:mod:`repro.kernels.autotune`).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.formats import EMFormat, GS_FMT_DEFAULT
from .mls_matmul import mls_matmul_pallas
from .mls_quantize import mls_quantize_pallas


def _pad_to(x: jax.Array, mult0: int, mult1: int) -> jax.Array:
    p0 = (-x.shape[0]) % mult0
    p1 = (-x.shape[1]) % mult1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


@partial(
    jax.jit,
    static_argnames=(
        "fmt", "gs_fmt", "k_block", "block_m", "block_n", "grouping",
        "interpret",
    ),
)
def lowbit_matmul_fused(
    x: jax.Array,
    w: jax.Array,
    key: jax.Array | None = None,
    *,
    fmt: EMFormat,
    gs_fmt: EMFormat = GS_FMT_DEFAULT,
    k_block: int = 128,
    block_m: int | None = None,
    block_n: int | None = None,
    grouping: str = "nc",
    interpret: bool | None = None,
) -> jax.Array:
    """Dynamically quantize ``x (M,K)`` and ``w (K,N)`` and multiply.

    Scaling groups follow ``grouping`` (paper Table IV): ``"nc"`` per
    (row, k-block), ``"c"`` per k-block shared across rows, ``"n"`` per
    row/column, ``"none"`` tensor-wise only.  Output tiles left at ``None``
    resolve through the autotuner cache.  Shapes are padded to tile
    multiples internally; the result is fp32 ``(M, N)`` and is
    bit-identical to the pure-jnp oracle pipeline
    (``kernels.ref.quantize_ref`` + ``kernels.ref.mls_matmul_ref``).
    """
    M, K = x.shape
    K2, N = w.shape
    assert K == K2
    if block_m is None or block_n is None:
        from .autotune import resolve_block_config  # lazy: avoids a cycle

        cfg = resolve_block_config(
            "gemm", (M, K, N), fmt, grouping,
            k_block=k_block, block_m=block_m, block_n=block_n,
        )
        block_m, block_n = cfg.block_m, cfg.block_n
    xp = _pad_to(x.astype(jnp.float32), block_m, k_block)
    wp = _pad_to(w.astype(jnp.float32), k_block, block_n)
    kx, kw = (None, None) if key is None else tuple(jax.random.split(key))
    xc, xsg, xst = mls_quantize_pallas(
        xp, fmt, k_block, gs_fmt, kx, block_m=block_m, interpret=interpret,
        grouping=grouping,
    )
    wc, wsgT, wst = mls_quantize_pallas(
        wp.T, fmt, k_block, gs_fmt, kw, block_m=block_n, interpret=interpret,
        grouping=grouping,
    )
    # weight was quantized transposed (groups along its K axis); the GEMM
    # kernel wants codes (K, N) and the transposed compact scale layout —
    # for every grouping the plain transpose is exactly that layout:
    # "nc" (N,K/kb)->(K/kb,N), "c" (1,K/kb)->(K/kb,1), "n" (N,1)->(1,N).
    y = mls_matmul_pallas(
        xc, xsg, xst, wc.T, wsgT.T, wst, fmt,
        k_block=k_block, block_m=block_m, block_n=block_n,
        grouping=grouping, interpret=interpret,
    )
    return y[:M, :N]
