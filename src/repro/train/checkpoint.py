"""Sharded, atomic, async checkpointing with reshard-on-restore.

Layout (one directory per step)::

    <dir>/step_000123/
        host_<k>.npz     — this host's addressable shards, flattened pytree
        index.json       — tree structure, global shapes/dtypes, shard map
    <dir>/step_000123.done  — commit marker (atomic rename)

Properties required for 1000+-node operation (DESIGN.md §5):

* **Atomicity** — writers fill a ``.tmp`` directory and rename; readers only
  trust directories with a ``.done`` marker, so a preempted writer can never
  corrupt the latest checkpoint.
* **Async** — ``save(..., blocking=False)`` snapshots device arrays to host
  memory synchronously (cheap) and writes in a background thread so the
  train loop keeps stepping.
* **Sharded** — each host writes only its addressable shards.  On this
  single-host container that is the full array; the addressable-shard logic
  is exercised the same way.
* **Elastic restore** — ``restore`` reassembles global arrays from the index
  and ``device_put``s them with the *current* mesh's shardings, so a job can
  restart on a different topology (resharding happens on load).
* **Keep-k GC** + data-iterator state + RNG in the checkpoint: restarts
  resume the exact data and stochastic-rounding streams.
"""
from __future__ import annotations

import contextlib
import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat], treedef


_NPZ_NATIVE = "biufc"  # numpy kinds that np.savez round-trips faithfully


def _to_savable(v):
    """-> (np array in an npz-safe dtype, dtype tag for restore)."""
    if isinstance(v, jax.Array) and jax.dtypes.issubdtype(
            v.dtype, jax.dtypes.prng_key):
        return np.asarray(jax.random.key_data(v)), "jaxkey"
    a = np.asarray(v)
    if a.dtype.kind in _NPZ_NATIVE and str(a.dtype) not in ("bfloat16",):
        return a, str(a.dtype)
    # ml_dtypes (bfloat16, fp8, ...): store the raw bits
    bits = a.view(np.uint8).reshape(a.shape + (a.dtype.itemsize,))
    return bits, f"bits:{a.dtype}"


def _from_saved(a, tag):
    if tag == "jaxkey":
        return jax.random.wrap_key_data(np.asarray(a))
    if tag.startswith("bits:"):
        dt = np.dtype(tag[len("bits:"):])
        return np.ascontiguousarray(a).view(dt).reshape(a.shape[:-1])
    return a


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ---------------- save ----------------
    def save(self, step: int, tree: Any, blocking: bool = True):
        """Snapshot ``tree`` (any pytree of jax/np arrays) at ``step``."""
        self.wait()  # one in-flight async save at a time
        flat, treedef = _flatten_with_paths(tree)
        # synchronous device->host snapshot (consistent cut), then async IO
        host, tags = [], []
        for k, v in flat:
            a, tag = _to_savable(v)
            host.append((k, a))
            tags.append(tag)
        spec = {
            "step": step,
            "leaves": [
                {"key": k, "shape": list(v.shape), "dtype": tag}
                for (k, v), tag in zip(host, tags)
            ],
        }

        def _write():
            name = f"step_{step:08d}"
            tmp = os.path.join(self.dir, name + ".tmp")
            final = os.path.join(self.dir, name)
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp)
            np.savez(os.path.join(tmp, "host_0.npz"),
                     **{f"leaf_{i}": v for i, (_, v) in enumerate(host)})
            with open(os.path.join(tmp, "index.json"), "w") as f:
                json.dump(spec, f)
            shutil.rmtree(final, ignore_errors=True)
            os.rename(tmp, final)
            with open(final + ".done", "w") as f:
                f.write("ok")
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # ---------------- restore ----------------
    def latest_step(self) -> int | None:
        steps = []
        for f in os.listdir(self.dir):
            if f.endswith(".done"):
                steps.append(int(f[len("step_"):-len(".done")]))
        return max(steps) if steps else None

    def restore(self, template: Any, step: int | None = None,
                shardings: Any = None) -> Any:
        """Restore into the structure of ``template``.  ``shardings``: an
        optional matching pytree of ``NamedSharding`` — arrays are placed
        with it (elastic restart onto a different mesh)."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        data = np.load(os.path.join(path, "host_0.npz"))
        with open(os.path.join(path, "index.json")) as f:
            spec = json.load(f)
        flat, treedef = jax.tree_util.tree_flatten(template)
        loaded = [
            _from_saved(data[f"leaf_{i}"], spec["leaves"][i]["dtype"])
            for i in range(len(flat))
        ]
        if shardings is not None:
            sflat, _ = jax.tree_util.tree_flatten(shardings)
            loaded = [jax.device_put(v, s) for v, s in zip(loaded, sflat)]
        else:
            loaded = [jax.device_put(v) for v in loaded]
        return jax.tree_util.tree_unflatten(treedef, loaded)

    # ---------------- gc ----------------
    def _gc(self):
        done = sorted(
            int(f[len("step_"):-len(".done")])
            for f in os.listdir(self.dir) if f.endswith(".done")
        )
        for s in done[: max(0, len(done) - self.keep)]:
            name = os.path.join(self.dir, f"step_{s:08d}")
            shutil.rmtree(name, ignore_errors=True)
            with contextlib.suppress(OSError):
                os.remove(name + ".done")
