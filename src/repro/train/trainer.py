"""Train/serve step builders: loss -> grads -> clip -> optimizer, with
microbatch gradient accumulation, deterministic per-step RNG, and the
optional MLS-compressed cross-pod gradient all-reduce.

``make_train_step`` returns a pure function suitable both for ``jax.jit``
execution and for the AOT multi-pod dry-run (``.lower().compile()``).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from collections.abc import Callable
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.models import lm
from repro.optim import clip_by_global_norm, cosine_schedule, make_optimizer
from repro.parallel import shard


def make_train_step(run: RunConfig, lr_fn: Callable | None = None):
    cfg = run.model
    opt_init, opt_update = make_optimizer(
        run.optimizer, weight_decay=run.weight_decay
    )
    lr_fn = lr_fn or cosine_schedule(run.lr, warmup=100, total=10_000)

    def loss_fn(params, batch, key):
        return lm.lm_loss(params, batch, cfg, key)

    def train_step(params, opt_state, batch):
        step = opt_state.step
        key = jax.random.fold_in(jax.random.key(run.seed), step)
        batch = jax.tree.map(lambda x: shard(x, "batch"), batch)

        if run.microbatch and run.microbatch > 1:
            n = run.microbatch

            def resh(x):
                b = x.shape[0]
                return x.reshape((n, b // n) + x.shape[1:])

            mbatch = jax.tree.map(resh, batch)

            def acc_body(carry, mb):
                g_acc, l_acc = carry
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb, key
                )
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g
                )
                return (g_acc, l_acc + l), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (grads, loss), _ = jax.lax.scan(
                acc_body, (zeros, jnp.float32(0.0)), mbatch
            )
            grads = jax.tree.map(lambda g: g / n, grads)
            loss = loss / n
            metrics: dict[str, Any] = {}
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True
            )(params, batch, key)

        if run.grad_compression:
            # cross-pod exchange of MLS-compressed gradients happens in the
            # launcher's shard_map wrapper; here we only tag the intent so
            # single-pod runs are unaffected.  See launch/train.py.
            pass

        grads, gnorm = clip_by_global_norm(grads, run.grad_clip)
        lr = lr_fn(step)
        params, opt_state = opt_update(grads, opt_state, params, lr)
        out_metrics = {
            "loss": loss.astype(jnp.float32),
            "grad_norm": gnorm,
            "lr": jnp.float32(lr),
        }
        return params, opt_state, out_metrics

    return train_step, opt_init


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, cache, tokens):
        return lm.decode_step(params, cache, tokens, cfg)

    return serve_step


def make_prefill_step(cfg: ModelConfig, max_len: int):
    def prefill_step(params, batch):
        return lm.prefill(params, batch, cfg, max_len)

    return prefill_step
