from .checkpoint import CheckpointManager
from .straggler import StragglerMonitor
from .trainer import make_prefill_step, make_serve_step, make_train_step

__all__ = [
    "CheckpointManager", "StragglerMonitor", "make_prefill_step",
    "make_serve_step", "make_train_step",
]
