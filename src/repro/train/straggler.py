"""Straggler / step-time monitoring.

On a TPU pod slice every host runs the same SPMD program, so a straggler
host stalls the whole step (collectives are synchronous).  Mitigation at
1000+ nodes is detection + preempt/restart-from-checkpoint (which
``CheckpointManager`` makes cheap); this module provides the detection:
an EMA step timer that flags steps (or, with per-host times fed in from an
out-of-band channel, hosts) exceeding ``threshold`` x the EMA.
"""
from __future__ import annotations

import dataclasses
import time


@dataclasses.dataclass
class StragglerMonitor:
    ema_decay: float = 0.9
    threshold: float = 2.0  # flag if step_time > threshold * ema
    warmup_steps: int = 3  # ignore compile-dominated first steps
    ema: float | None = None
    steps: int = 0
    flagged: list[int] = dataclasses.field(default_factory=list)
    _t0: float | None = None

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self) -> float:
        dt = time.perf_counter() - self._t0
        self.steps += 1
        if self.steps <= self.warmup_steps:
            return dt
        if self.ema is None:
            self.ema = dt
        slow = dt > self.threshold * self.ema
        if slow:
            self.flagged.append(self.steps)
        self.ema = self.ema_decay * self.ema + (1 - self.ema_decay) * dt
        return dt

    def report(self) -> dict:
        return {
            "steps": self.steps,
            "ema_step_time_s": self.ema,
            "straggler_steps": list(self.flagged),
        }
