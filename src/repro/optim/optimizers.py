"""Optimizers (fp32 state) + schedules.

The paper keeps the weight update in full precision (Alg. 1 l.13 and
Table VI "SGD Update" rows): master weights, momenta and the update itself
are fp32 regardless of the low-bit conv/GEMM format.

* ``sgdm``  — SGD + momentum + weight decay (the paper's CNN recipe:
  momentum 0.9, wd 5e-4, step-decayed lr).
* ``adamw`` — decoupled weight decay Adam (LM runs).
"""
from __future__ import annotations

import dataclasses
from collections.abc import Callable
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jax.Array
    mu: Any  # first moment / momentum (pytree, fp32)
    nu: Any  # second moment (pytree, fp32; () leaves for sgdm)


def _f32(tree):
    return jax.tree.map(lambda p: p.astype(jnp.float32), tree)


def clip_by_global_norm(grads, max_norm: float):
    gn = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    )
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gn


# ---------------------------------------------------------------------------
# SGD + momentum (paper CNN recipe)
# ---------------------------------------------------------------------------
def sgdm_init(params) -> OptState:
    return OptState(jnp.int32(0), jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params), ())


def sgdm_update(grads, state: OptState, params, lr, momentum=0.9, weight_decay=5e-4):
    def upd(g, m, p):
        g = g.astype(jnp.float32) + weight_decay * p.astype(jnp.float32)
        m = momentum * m + g
        return m, (p.astype(jnp.float32) - lr * m).astype(p.dtype)

    flat = jax.tree.map(upd, grads, state.mu, params)
    mu = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda t: isinstance(t, tuple))
    new_p = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda t: isinstance(t, tuple))
    return new_p, OptState(state.step + 1, mu, ())


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------
def adamw_init(params) -> OptState:
    z = lambda: jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return OptState(jnp.int32(0), z(), z())


def adamw_update(grads, state: OptState, params, lr, b1=0.9, b2=0.95,
                 eps=1e-8, weight_decay=0.1):
    t = state.step + 1
    c1 = 1.0 - b1 ** t.astype(jnp.float32)
    c2 = 1.0 - b2 ** t.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        u = (m / c1) / (jnp.sqrt(v / c2) + eps)
        newp = p.astype(jnp.float32) * (1.0 - lr * weight_decay) - lr * u
        return m, v, newp.astype(p.dtype)

    flat = jax.tree.map(upd, grads, state.mu, state.nu, params)
    is3 = lambda t: isinstance(t, tuple)
    mu = jax.tree.map(lambda t: t[0], flat, is_leaf=is3)
    nu = jax.tree.map(lambda t: t[1], flat, is_leaf=is3)
    new_p = jax.tree.map(lambda t: t[2], flat, is_leaf=is3)
    return new_p, OptState(t, mu, nu)


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------
def step_decay_schedule(base_lr: float, boundaries, factor=0.1):
    """Paper: lr/10 at epochs 80/120 (CIFAR) or every 30 epochs (ImageNet)."""

    def lr(step):
        step = jnp.asarray(step)
        mult = jnp.float32(1.0)
        for b in boundaries:
            mult = mult * jnp.where(step >= b, factor, 1.0)
        return base_lr * mult

    return lr


def cosine_schedule(base_lr: float, warmup: int, total: int, min_frac=0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)

    return lr


def make_optimizer(name: str, **kw) -> tuple[Callable, Callable]:
    if name == "sgdm":
        return sgdm_init, lambda g, s, p, lr: sgdm_update(g, s, p, lr, **kw)
    if name == "adamw":
        return adamw_init, lambda g, s, p, lr: adamw_update(g, s, p, lr, **kw)
    raise ValueError(name)
