from .optimizers import (
    OptState,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    cosine_schedule,
    make_optimizer,
    sgdm_init,
    sgdm_update,
    step_decay_schedule,
)

__all__ = [
    "OptState", "adamw_init", "adamw_update", "clip_by_global_norm",
    "cosine_schedule", "make_optimizer", "sgdm_init", "sgdm_update",
    "step_decay_schedule",
]
