"""Paper Table IV / Fig. 7 row 1-3 ablations: ARE of quantization under
(grouping dims) x (Mg) x (Ex) x (Mx), on realistic tensor statistics
(per-(n,c) scale diversity like real activations/errors)."""
import time

import jax
import jax.numpy as jnp

from repro.core import EMFormat, GroupSpec, average_relative_error, mls_quantize

GROUPINGS = {
    "1": None,  # no group scaling
    "c": GroupSpec((None, 1, None, None)),
    "n": GroupSpec((1, None, None, None)),
    "nc": GroupSpec.conv_nc(),
}


def _tensor(key):
    """Activation-like: per-(n,c) scales spanning ~3 decades (cf. Fig. 6)."""
    k1, k2 = jax.random.split(key)
    scales = 10.0 ** jax.random.uniform(k1, (16, 32, 1, 1), minval=-2.0, maxval=1.0)
    return jax.random.normal(k2, (16, 32, 8, 8)) * scales


def run(quick: bool = True):
    x = _tensor(jax.random.key(0))
    rows = []
    t0 = time.perf_counter()
    # --- grouping dim ablation (Ex=0 equivalent: <0,3>) --------------------
    for gname, spec in GROUPINGS.items():
        for mg in (0, 1):
            gs = EMFormat(8, mg)
            are = float(average_relative_error(
                x, mls_quantize(x, EMFormat(0, 3), spec, gs).dequant()))
            rows.append((f"table4/group_{gname}_mg{mg}_e0m3", 0.0,
                         f"ARE={are:.4f}"))
    # --- element exponent ablation (no grouping) ---------------------------
    for ex in (0, 1, 2):
        fmt = EMFormat(ex, 3)
        are = float(average_relative_error(
            x, mls_quantize(x, fmt, None).dequant()))
        rows.append((f"table4/nogroup_e{ex}m3", 0.0, f"ARE={are:.4f}"))
    # --- joint (nc, Mg=1) x Ex x Mx grid ------------------------------------
    for ex in (0, 1, 2):
        for mx in (1, 2, 3, 4):
            fmt = EMFormat(ex, mx)
            are = float(average_relative_error(
                x, mls_quantize(x, fmt, GroupSpec.conv_nc(),
                                EMFormat(8, 1)).dequant()))
            rows.append((f"table4/nc_mg1_e{ex}m{mx}", 0.0, f"ARE={are:.4f}"))
    us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
    return [(n, us, d) for n, _, d in rows]
