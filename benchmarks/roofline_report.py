"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline
source).  Reads experiments/dryrun/*.json written by repro.launch.dryrun."""
import glob
import json
import os
import time

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def load_records(art_dir=None):
    recs = []
    for f in sorted(glob.glob(os.path.join(art_dir or ART_DIR, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def fmt_row(r):
    rf = r["roofline"]
    return (
        f"compute={rf['compute_s']:.2e}s mem={rf['memory_s']:.2e}s "
        f"coll={rf['collective_s']:.2e}s bound={rf['bottleneck']} "
        f"frac={rf['roofline_fraction']:.3f} util={rf['model_flops_ratio']:.2f} "
        f"mb={r.get('microbatch', 0)}"
    )


def run(quick: bool = True):
    rows = []
    for r in load_records():
        name = f"roofline/{r['arch']}_{r['shape']}_{r['mesh']}"
        if r.get("tag"):
            name += f"_{r['tag']}"
        rows.append((name, r.get("compile_s", 0) * 1e6, fmt_row(r)))
    if not rows:
        rows.append(("roofline/none", 0.0,
                     "no artifacts; run python -m repro.launch.dryrun --all"))
    return rows
