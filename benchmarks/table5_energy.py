"""Paper Table V + Eq. 12: MAC-unit energies and the 3x3-conv energy ratio."""
import time

from repro.energy import MAC_ENERGY_PJ, conv_energy_ratio


def run(quick: bool = True):
    t0 = time.perf_counter()
    rows = []
    for fw, e in MAC_ENERGY_PJ.items():
        rows.append((f"table5/{fw}", 0.0,
                     f"mul={e['mul']}pJ acc={e['acc']}pJ"))
    r = conv_energy_ratio(3)
    rows.append(("table5/eq12_conv3x3_ratio", 0.0,
                 f"{r:.2f}x (paper ~11.5x)"))
    us = (time.perf_counter() - t0) * 1e6 / len(rows)
    return [(n, us, d) for n, _, d in rows]
