"""Paper Fig. 7: per-layer ARE of weight / activation / error on a (reduced)
ResNet-20 forward/backward over synthetic CIFAR.

"Error" is dL/dZ per block (captured exactly by differentiating w.r.t. a
zero perturbation added to each block output), "activation" is each block's
input, "weight" each block's conv1 kernel — the same three tensor kinds the
paper quantizes.
"""
import time

import jax
import jax.numpy as jnp

from repro.core import (
    FMT_CIFAR, GroupSpec, average_relative_error, mls_quantize,
)
from repro.data import make_cifar_iterator
from repro.models.cnn import CNNConfig, _RESNET_STAGES, _block, init_cnn
from repro.models import nn


def _forward_with_taps(params, x, cfg, zs):
    depths, widths, _ = _RESNET_STAGES[cfg.arch]
    h = nn.conv2d(params["stem"], x, 1, "SAME", None)
    h = jax.nn.relu(nn.batchnorm(params["bn_stem"], h))
    acts = []
    bi = 0
    for si, d in enumerate(depths):
        for bj in range(d):
            stride = 2 if (bj == 0 and si > 0) else 1
            acts.append(h)
            h = _block(params["blocks"][bi], h, stride, None, None, 0) + zs[bi]
            bi += 1
    h = jnp.mean(h, axis=(2, 3))
    return nn.linear(params["fc"], h, None), acts


def run(quick: bool = True):
    cfg = CNNConfig(arch="resnet20", num_classes=10, width_mult=0.5, in_hw=16)
    params = init_cnn(jax.random.key(0), cfg)
    nxt, ds = make_cifar_iterator(batch=16, hw=16)
    batch, _ = nxt(ds)

    # shapes of each block output (for the zero perturbations)
    zs0 = []
    h = batch["image"]
    depths, widths, _ = _RESNET_STAGES[cfg.arch]
    widths = [cfg.scaled(w) for w in widths]
    hw = cfg.in_hw
    for si, d in enumerate(depths):
        for bj in range(d):
            if bj == 0 and si > 0:
                hw //= 2
            zs0.append(jnp.zeros((16, widths[si], hw, hw)))

    def loss_fn(zs):
        logits, _ = _forward_with_taps(params, batch["image"], cfg, zs)
        ll = jax.nn.log_softmax(logits)
        return -jnp.take_along_axis(ll, batch["label"][:, None], 1).mean()

    errors = jax.grad(loss_fn)(zs0)  # dL/dZ per block  (paper's "error")
    _, acts = _forward_with_taps(params, batch["image"], cfg, zs0)
    weights = [b["conv1"]["w"] for b in params["blocks"]]

    t0 = time.perf_counter()
    rows = []
    for kind, tensors in (("weight", weights), ("act", acts), ("err", errors)):
        for spec_name, spec in (("nc", GroupSpec.conv_nc()), ("none", None)):
            ares = [
                float(average_relative_error(
                    x, mls_quantize(x, FMT_CIFAR, spec).dequant()))
                for x in tensors
            ]
            mean = sum(ares) / len(ares)
            rows.append((
                f"fig7/{kind}_{spec_name}", 0.0,
                f"mean_ARE={mean:.4f} layers={['%.3f' % a for a in ares[:6]]}",
            ))
    us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
    return [(n, us, d) for n, _, d in rows]
