"""Shared ``BENCH_*.json`` row emission for the benchmark scripts.

The canonical implementation lives in :mod:`repro.sweep.record` (so the
installed package stamps artifacts without needing the ``benchmarks/``
directory on ``sys.path``); this shim is the script-side import point.
Every payload and every row is stamped with ``schema_version`` +
``git_sha`` so nightly artifacts are comparable across commits.
"""
from repro.sweep.record import (  # noqa: F401
    SCHEMA_VERSION,
    git_sha,
    make_payload,
    stamp_rows,
    write_json,
)

__all__ = ["SCHEMA_VERSION", "git_sha", "make_payload", "stamp_rows", "write_json"]
