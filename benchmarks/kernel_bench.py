"""Pallas kernel micro-benchmarks (interpret mode on CPU: correctness-grade
timings, structural not wall-clock-representative of TPU).

Runs inside the ``benchmarks/run.py`` CSV driver, or standalone with a JSON
artifact for the CI perf trail::

    PYTHONPATH=src python benchmarks/kernel_bench.py --json BENCH_kernels.json
"""
import argparse
import json
import platform
import time

import jax

from repro.core import FMT_IMAGENET, QuantConfig, lowbit_conv, lowbit_matmul
from repro.kernels import KERNEL_REGISTRY, lowbit_conv_fused


def _time(f, *args, n=3):
    f(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / n * 1e6


def run(quick: bool = True):
    # Pallas entry points come from KERNEL_REGISTRY — the same set the
    # static verifier (analysis/kernel_verify.py) proves, so the perf trail
    # and the legality gate can never drift apart.
    rows = []
    for entry in KERNEL_REGISTRY.values():
        if not entry.bench:
            continue
        fn, _ = entry.fn_and_args()
        args = entry.concrete_args()
        us = _time(jax.jit(fn), *args)
        rows.append((f"kernel/{entry.name}_{entry.bench_tag}", us,
                     "interpret-mode"))

    # hand-coded XLA reference rows (not Pallas kernels, so not registered)
    x = jax.random.normal(jax.random.key(0), (256, 512))
    w = jax.random.normal(jax.random.key(1), (512, 256)) * 0.05
    cfg = QuantConfig(fmt=FMT_IMAGENET, stochastic=False)
    us = _time(jax.jit(lambda a, b: lowbit_matmul(a, b, None, cfg)), x, w)
    rows.append(("kernel/lowbit_matmul_fakequant_jit", us, "XLA-fused reference"))
    us = _time(jax.jit(lambda a, b: a @ b), x, w)
    rows.append(("kernel/fp32_matmul_jit", us, "baseline"))

    # conv backends: fake-quant XLA reference (+ a bigger Pallas shape with
    # --full; the quick Pallas conv row is the registry's example shape)
    n, c, o, hw = (2, 16, 16, 8) if quick else (8, 32, 32, 16)
    xc = jax.random.normal(jax.random.key(2), (n, c, hw, hw))
    wc = jax.random.normal(jax.random.key(3), (o, c, 3, 3)) * 0.1
    tag = f"{n}x{c}x{hw}x{hw}_o{o}k3"
    if not quick:
        cfg_p = QuantConfig(fmt=FMT_IMAGENET, stochastic=False,
                            backend="pallas", k_block=32)
        us = _time(
            jax.jit(lambda a, b: lowbit_conv_fused(a, b, None, (1, 1), "SAME",
                                                   cfg_p)),
            xc, wc,
        )
        rows.append((f"kernel/lowbit_conv_fused_{tag}", us, "interpret-mode"))
    us = _time(
        jax.jit(lambda a, b: lowbit_conv(a, b, None, (1, 1), "SAME", cfg)),
        xc, wc,
    )
    rows.append((f"kernel/lowbit_conv_fakequant_jit_{tag}", us,
                 "XLA-fused reference"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true",
                    help="larger shapes (still interpret mode off-TPU)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write rows as a BENCH_*.json artifact")
    args = ap.parse_args()
    rows = run(quick=not args.full)
    for name, us, derived in rows:
        print(f'{name},{us:.1f},"{derived}"', flush=True)
    if args.json:
        payload = {
            "suite": "kernel_bench",
            "unix_time": time.time(),
            "backend": jax.default_backend(),
            "machine": platform.machine(),
            "quick": not args.full,
            "rows": [
                {"name": n, "us_per_call": round(us, 1), "derived": d}
                for n, us, d in rows
            ],
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
