"""Pallas kernel micro-benchmarks (interpret mode on CPU: correctness-grade
timings, structural not wall-clock-representative of TPU).

For every bench-flagged ``KERNEL_REGISTRY`` entry with a tuning spec, two
rows are emitted: the *static default* tiling and the *tuned* tiling
resolved from the persistent autotuner cache (``kernels/tuned/
kernel_tune.json`` seed + local overlay) — served from the cache without
re-timing the search.  Rows carry ``blocks``/``grouping``/``tuned`` fields
in the JSON artifact so the perf trail records which tiling produced each
number.

Runs inside the ``benchmarks/run.py`` CSV driver, or standalone with a JSON
artifact for the CI perf trail::

    PYTHONPATH=src python benchmarks/kernel_bench.py --json BENCH_kernels.json
"""
import argparse
import json
import platform
import time

import jax

from repro.core import FMT_IMAGENET, QuantConfig, lowbit_conv, lowbit_matmul
from repro.kernels import KERNEL_REGISTRY, lowbit_conv_fused
from repro.kernels.autotune import (
    default_block_config,
    get_cache,
    time_config,
)


def _time(f, *args, n=5):
    """Best-of-n wall time in us (min is far more noise-robust than mean
    for micro-benchmarks: noise is one-sided)."""
    f(*args)  # compile
    best = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        jax.block_until_ready(f(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _row(name, us, derived, config=None, tuned=None, cached=None):
    r = {"name": name, "us_per_call": round(us, 1), "derived": derived}
    if config is not None:
        r["blocks"] = {
            "block_m": config.block_m, "block_n": config.block_n,
            "k_block": config.k_block,
        }
        r["grouping"] = config.grouping
    if tuned is not None:
        r["tuned"] = tuned
    if cached is not None:
        r["cached"] = cached
    return r


def _tuned_rows(entry, cache):
    """(default, tuned) row pair for one registry entry's tuning spec.

    The tuned tiling is *resolved* from the cache, never re-searched here;
    when the cached winner equals the static default, the default's
    measurement is reused (so tuned <= default holds by construction in
    the degenerate case)."""
    spec = entry.tune
    base = f"kernel/{entry.name}_{entry.bench_tag}"
    default_cfg = default_block_config(spec)
    winner = cache.get(spec.key())
    us_default = time_config(spec, default_cfg, n=5)
    if winner is None or winner == default_cfg:
        us_tuned, tuned_cfg = us_default, default_cfg
    else:
        us_tuned, tuned_cfg = time_config(spec, winner, n=5), winner
    return [
        _row(f"{base}_default", us_default, "interpret-mode",
             config=default_cfg, tuned=False),
        _row(f"{base}_tuned", us_tuned, "interpret-mode",
             config=tuned_cfg, tuned=True, cached=winner is not None),
    ]


def run(quick: bool = True):
    # Pallas entry points come from KERNEL_REGISTRY — the same set the
    # static verifier (analysis/kernel_verify.py) proves and the autotuner
    # tunes, so the perf trail, the legality gate and the tuning cache can
    # never drift apart.
    cache = get_cache()
    rows = []
    for entry in KERNEL_REGISTRY.values():
        if not entry.bench:
            continue
        fn, _ = entry.fn_and_args()
        args = entry.concrete_args()
        us = _time(jax.jit(fn), *args)
        rows.append(_row(f"kernel/{entry.name}_{entry.bench_tag}", us,
                         "interpret-mode"))
        if entry.tune is not None:
            rows += _tuned_rows(entry, cache)

    # hand-coded XLA reference rows (not Pallas kernels, so not registered)
    x = jax.random.normal(jax.random.key(0), (256, 512))
    w = jax.random.normal(jax.random.key(1), (512, 256)) * 0.05
    cfg = QuantConfig(fmt=FMT_IMAGENET, stochastic=False)
    us = _time(jax.jit(lambda a, b: lowbit_matmul(a, b, None, cfg)), x, w)
    rows.append(_row("kernel/lowbit_matmul_fakequant_jit", us,
                     "XLA-fused reference"))
    us = _time(jax.jit(lambda a, b: a @ b), x, w)
    rows.append(_row("kernel/fp32_matmul_jit", us, "baseline"))

    # conv backends: fake-quant XLA reference (+ a bigger Pallas shape with
    # --full; the quick Pallas conv row is the registry's example shape)
    n, c, o, hw = (2, 16, 16, 8) if quick else (8, 32, 32, 16)
    xc = jax.random.normal(jax.random.key(2), (n, c, hw, hw))
    wc = jax.random.normal(jax.random.key(3), (o, c, 3, 3)) * 0.1
    tag = f"{n}x{c}x{hw}x{hw}_o{o}k3"
    if not quick:
        cfg_p = QuantConfig(fmt=FMT_IMAGENET, stochastic=False,
                            backend="pallas", k_block=32)
        us = _time(
            jax.jit(lambda a, b: lowbit_conv_fused(a, b, None, (1, 1), "SAME",
                                                   cfg_p)),
            xc, wc,
        )
        rows.append(_row(f"kernel/lowbit_conv_fused_{tag}", us,
                         "interpret-mode"))
    us = _time(
        jax.jit(lambda a, b: lowbit_conv(a, b, None, (1, 1), "SAME", cfg)),
        xc, wc,
    )
    rows.append(_row(f"kernel/lowbit_conv_fakequant_jit_{tag}", us,
                     "XLA-fused reference"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true",
                    help="larger shapes (still interpret mode off-TPU)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write rows as a BENCH_*.json artifact")
    args = ap.parse_args()
    rows = run(quick=not args.full)
    for r in rows:
        print(f'{r["name"]},{r["us_per_call"]:.1f},"{r["derived"]}"',
              flush=True)
    if args.json:
        payload = {
            "suite": "kernel_bench",
            "unix_time": time.time(),
            "backend": jax.default_backend(),
            "machine": platform.machine(),
            "quick": not args.full,
            "rows": rows,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
