"""Pallas kernel micro-benchmarks (interpret mode on CPU: correctness-grade
timings, structural not wall-clock-representative of TPU)."""
import time

import jax
import jax.numpy as jnp

from repro.core import FMT_IMAGENET, QuantConfig, lowbit_matmul
from repro.kernels import lowbit_matmul_fused, mls_quantize_pallas


def _time(f, *args, n=3):
    f(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / n * 1e6


def run(quick: bool = True):
    m = 256
    x = jax.random.normal(jax.random.key(0), (m, 512))
    w = jax.random.normal(jax.random.key(1), (512, 256)) * 0.05
    rows = []
    us = _time(lambda a: mls_quantize_pallas(a, FMT_IMAGENET), x)
    rows.append(("kernel/mls_quantize_pallas_256x512", us, "interpret-mode"))
    us = _time(lambda a, b: lowbit_matmul_fused(a, b, None, fmt=FMT_IMAGENET), x, w)
    rows.append(("kernel/lowbit_matmul_fused_256x512x256", us, "interpret-mode"))
    cfg = QuantConfig(fmt=FMT_IMAGENET, stochastic=False)
    us = _time(jax.jit(lambda a, b: lowbit_matmul(a, b, None, cfg)), x, w)
    rows.append(("kernel/lowbit_matmul_fakequant_jit", us, "XLA-fused reference"))
    us = _time(jax.jit(lambda a, b: a @ b), x, w)
    rows.append(("kernel/fp32_matmul_jit", us, "baseline"))
    return rows
