"""Pallas kernel micro-benchmarks (interpret mode on CPU: correctness-grade
timings, structural not wall-clock-representative of TPU).

For every bench-flagged ``KERNEL_REGISTRY`` entry with a tuning spec, two
rows are emitted: the *static default* tiling and the *tuned* tiling
resolved from the persistent autotuner cache (``kernels/tuned/
kernel_tune.json`` seed + local overlay) — served from the cache without
re-timing the search.  Rows carry ``blocks``/``grouping``/``tuned`` fields
in the JSON artifact so the perf trail records which tiling produced each
number, plus a ``peak_hbm_bytes`` bytes-moved estimate (interpret mode has
no HBM counters; the estimators live in ``repro.kernels.implicit_conv``).
The im2col-vs-implicit conv comparison rows assert the implicit path moves
>= 3x fewer bytes on the ResNet-20 CIFAR conv shape.

Runs inside the ``benchmarks/run.py`` CSV driver, or standalone with a JSON
artifact for the CI perf trail::

    PYTHONPATH=src python benchmarks/kernel_bench.py --json BENCH_kernels.json
"""
import argparse
import time

import jax

try:
    from benchmarks._record import make_payload, write_json
except ImportError:  # run as a script: benchmarks/ itself is sys.path[0]
    from _record import make_payload, write_json

from repro.core import FMT_IMAGENET, QuantConfig, lowbit_conv, lowbit_matmul
from repro.kernels import (
    KERNEL_REGISTRY,
    conv_geometry,
    im2col_conv_bytes,
    implicit_conv_bytes,
    lowbit_conv_fused,
)
from repro.kernels.autotune import (
    default_block_config,
    get_cache,
    time_config,
)

# ResNet-20 CIFAR's dominant conv shape — the acceptance target for the
# implicit path's traffic win (estimator, not wall clock: interpret mode
# has no HBM counters)
_RESNET20_CONV = ((8, 16, 32, 32), (16, 16, 3, 3))
_MIN_IMPLICIT_BYTES_RATIO = 3.0


def _gemm_bytes(M: int, K: int, N: int, bm: int = 128, bn: int = 128) -> int:
    """Bytes-moved model of the fused GEMM: fp32 operands in, u8 codes
    written + re-fetched per output-tile sweep, fp32 out."""
    bm, bn = min(bm, M), min(bn, N)
    return (4 * (M * K + K * N)          # fp32 operands read by quantizers
            + 2 * (M * K + K * N)        # codes written, then first fetch
            + (M * K * (N // bn - 1) + K * N * (M // bm - 1))  # re-fetches
            + 4 * M * N)                 # fp32 output


def _entry_bytes(entry, config=None) -> int | None:
    """``peak_hbm_bytes`` estimate for a registry row (None when the
    entry's traffic has no model — nothing currently lacks one)."""
    spec = entry.tune
    bm = config.block_m if config is not None else 128
    bn = config.block_n if config is not None else 128
    if entry.name == "lowbit_conv_fused":
        geom = conv_geometry((2, 16, 8, 8), (16, 16, 3, 3), (1, 1), "SAME")
        return im2col_conv_bytes(geom, 32)["total"]
    if entry.name == "lowbit_conv_implicit":
        geom = conv_geometry((2, 16, 8, 8), (16, 16, 3, 3), (1, 1), "SAME")
        if config is not None and config.impl == "im2col":
            return im2col_conv_bytes(geom, 36, block_m=bm,
                                     block_n=bn)["total"]
        bh = config.block_m if config is not None else None
        bn_ = config.block_n if config is not None else None
        return implicit_conv_bytes(geom, 36, bh=bh, block_n=bn_)["total"]
    if spec is not None and spec.kind == "gemm":
        M, K, N = spec.shape
        return _gemm_bytes(M, K, N, bm, bn)
    if spec is not None and spec.kind == "quantize":
        M, K = spec.shape
        return 4 * M * K + M * K  # fp32 in, u8 codes out
    return None


def _time(f, *args, n=5):
    """Best-of-n wall time in us (min is far more noise-robust than mean
    for micro-benchmarks: noise is one-sided)."""
    f(*args)  # compile
    best = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        jax.block_until_ready(f(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _row(name, us, derived, config=None, tuned=None, cached=None,
         hbm_bytes=None):
    r = {"name": name, "derived": derived}
    if us is not None:
        r["us_per_call"] = round(us, 1)
    if config is not None:
        r["blocks"] = {
            "block_m": config.block_m, "block_n": config.block_n,
            "k_block": config.k_block,
        }
        if getattr(config, "impl", ""):
            r["blocks"]["impl"] = config.impl
        r["grouping"] = config.grouping
    if tuned is not None:
        r["tuned"] = tuned
    if cached is not None:
        r["cached"] = cached
    if hbm_bytes is not None:
        r["peak_hbm_bytes"] = int(hbm_bytes)
    return r


def _tuned_rows(entry, cache):
    """(default, tuned) row pair for one registry entry's tuning spec.

    The tuned tiling is *resolved* from the cache, never re-searched here;
    when the cached winner equals the static default, the default's
    measurement is reused (so tuned <= default holds by construction in
    the degenerate case)."""
    spec = entry.tune
    base = f"kernel/{entry.name}_{entry.bench_tag}"
    default_cfg = default_block_config(spec)
    winner = cache.get(spec.key())
    us_default = time_config(spec, default_cfg, n=5)
    if winner is None or winner == default_cfg:
        us_tuned, tuned_cfg = us_default, default_cfg
    else:
        us_tuned, tuned_cfg = time_config(spec, winner, n=5), winner
    return [
        _row(f"{base}_default", us_default, "interpret-mode",
             config=default_cfg, tuned=False,
             hbm_bytes=_entry_bytes(entry, default_cfg)),
        _row(f"{base}_tuned", us_tuned, "interpret-mode",
             config=tuned_cfg, tuned=True, cached=winner is not None,
             hbm_bytes=_entry_bytes(entry, tuned_cfg)),
    ]


def _conv_impl_rows(quick: bool):
    """im2col-vs-implicit comparison: timed on the quick registry shape,
    estimator-only on the ResNet-20 CIFAR shape (the acceptance target —
    asserted, so the perf trail cannot silently regress the traffic win)."""
    rows = []
    shapes = [("2x16x8x8_o16k3", (2, 16, 8, 8), (16, 16, 3, 3), True)]
    xs, ws = _RESNET20_CONV
    tag = f"resnet20_{'x'.join(str(d) for d in xs)}_o{ws[0]}k3"
    shapes.append((tag, xs, ws, not quick))
    for tag, xshape, wshape, timed in shapes:
        geom = conv_geometry(xshape, wshape, (1, 1), "SAME")
        est = {
            "im2col": im2col_conv_bytes(geom, 36)["total"],
            "implicit": implicit_conv_bytes(geom, 36)["total"],
        }
        ratio = est["im2col"] / est["implicit"]
        assert ratio >= _MIN_IMPLICIT_BYTES_RATIO, (
            f"implicit conv must move >= {_MIN_IMPLICIT_BYTES_RATIO}x fewer "
            f"HBM bytes than im2col on {tag}: got {ratio:.2f}x"
        )
        for impl in ("im2col", "implicit"):
            us = None
            if timed:
                cfg = QuantConfig(fmt=FMT_IMAGENET, stochastic=False,
                                  backend="pallas", k_block=36,
                                  conv_impl=impl)
                x = jax.random.normal(jax.random.key(4), xshape)
                w = jax.random.normal(jax.random.key(5), wshape) * 0.1
                us = _time(
                    jax.jit(lambda a, b, c=cfg: lowbit_conv_fused(
                        a, b, None, (1, 1), "SAME", c)),
                    x, w,
                )
            r = _row(f"kernel/conv_{impl}_{tag}", us,
                     "interpret-mode" if timed else "bytes-model only",
                     hbm_bytes=est[impl])
            r["im2col_over_implicit_bytes"] = round(ratio, 2)
            rows.append(r)
    return rows


def run(quick: bool = True):
    # Pallas entry points come from KERNEL_REGISTRY — the same set the
    # static verifier (analysis/kernel_verify.py) proves and the autotuner
    # tunes, so the perf trail, the legality gate and the tuning cache can
    # never drift apart.
    cache = get_cache()
    rows = []
    for entry in KERNEL_REGISTRY.values():
        if not entry.bench:
            continue
        fn, _ = entry.fn_and_args()
        args = entry.concrete_args()
        us = _time(jax.jit(fn), *args)
        rows.append(_row(f"kernel/{entry.name}_{entry.bench_tag}", us,
                         "interpret-mode", hbm_bytes=_entry_bytes(entry)))
        if entry.tune is not None:
            rows += _tuned_rows(entry, cache)

    rows += _conv_impl_rows(quick)

    # hand-coded XLA reference rows (not Pallas kernels, so not registered)
    x = jax.random.normal(jax.random.key(0), (256, 512))
    w = jax.random.normal(jax.random.key(1), (512, 256)) * 0.05
    cfg = QuantConfig(fmt=FMT_IMAGENET, stochastic=False)
    fp32_io = 4 * (x.size + w.size + x.shape[0] * w.shape[1])
    us = _time(jax.jit(lambda a, b: lowbit_matmul(a, b, None, cfg)), x, w)
    rows.append(_row("kernel/lowbit_matmul_fakequant_jit", us,
                     "XLA-fused reference", hbm_bytes=fp32_io))
    us = _time(jax.jit(lambda a, b: a @ b), x, w)
    rows.append(_row("kernel/fp32_matmul_jit", us, "baseline",
                     hbm_bytes=fp32_io))

    # conv backends: fake-quant XLA reference (+ a bigger Pallas shape with
    # --full; the quick Pallas conv row is the registry's example shape)
    n, c, o, hw = (2, 16, 16, 8) if quick else (8, 32, 32, 16)
    xc = jax.random.normal(jax.random.key(2), (n, c, hw, hw))
    wc = jax.random.normal(jax.random.key(3), (o, c, 3, 3)) * 0.1
    tag = f"{n}x{c}x{hw}x{hw}_o{o}k3"
    if not quick:
        cfg_p = QuantConfig(fmt=FMT_IMAGENET, stochastic=False,
                            backend="pallas", k_block=32)
        us = _time(
            jax.jit(lambda a, b: lowbit_conv_fused(a, b, None, (1, 1), "SAME",
                                                   cfg_p)),
            xc, wc,
        )
        rows.append(_row(f"kernel/lowbit_conv_fused_{tag}", us,
                         "interpret-mode"))
    us = _time(
        jax.jit(lambda a, b: lowbit_conv(a, b, None, (1, 1), "SAME", cfg)),
        xc, wc,
    )
    geom = conv_geometry(xc.shape, wc.shape, (1, 1), "SAME")
    rows.append(_row(
        f"kernel/lowbit_conv_fakequant_jit_{tag}", us,
        "XLA-fused reference",
        hbm_bytes=4 * (xc.size + wc.size + geom.m0 * geom.o)))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true",
                    help="larger shapes (still interpret mode off-TPU)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write rows as a BENCH_*.json artifact")
    args = ap.parse_args()
    rows = run(quick=not args.full)
    for r in rows:
        us = r.get("us_per_call")
        print(f'{r["name"]},{"" if us is None else f"{us:.1f}"},'
              f'"{r["derived"]}"', flush=True)
    if args.json:
        write_json(args.json, make_payload("kernel_bench", rows,
                                           quick=not args.full))


if __name__ == "__main__":
    main()
