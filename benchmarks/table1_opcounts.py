"""Paper Table I: op counts of one ImageNet training step (per image)."""
import time

from repro.models.cnn import CNNConfig, count_ops

PAPER = {  # (fwd conv MACs, fc MACs, ew-adds)
    "resnet18": (1.88e9, 5.12e5, 7.53e5),
    "googlenet": (1.58e9, 1.02e6, 0.0),
}


def run(quick: bool = True):
    rows = []
    for arch, (conv_ref, fc_ref, ew_ref) in PAPER.items():
        t0 = time.perf_counter()
        ops = count_ops(CNNConfig(arch=arch, num_classes=1000, in_hw=224))
        us = (time.perf_counter() - t0) * 1e6
        conv = sum(d["c_in"] * d["c_out"] * d["k"] ** 2 * d["h"] * d["w"]
                   for k, d in ops if k == "conv")
        fc = sum(d["d_in"] * d["d_out"] * d["rows"] for k, d in ops if k == "fc")
        ew = sum(d["numel"] for k, d in ops if k == "ew_add")
        rows.append((f"table1/{arch}_conv_macs", us,
                     f"{conv:.3e} (paper {conv_ref:.2e})"))
        rows.append((f"table1/{arch}_fc_macs", us,
                     f"{fc:.3e} (paper {fc_ref:.2e})"))
        rows.append((f"table1/{arch}_ew_adds", us,
                     f"{ew:.3e} (paper {ew_ref:.2e})"))
    return rows
