"""Benchmark driver: one module per paper table/figure + roofline + kernels.

Prints ``name,us_per_call,derived`` CSV (scaffold contract).  ``--full`` runs
the longer training-proxy settings.
"""
import argparse
import pathlib
import sys
import traceback

if __package__ in (None, ""):  # script mode: `python benchmarks/run.py`
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

from benchmarks import (
    fig7_are,
    kernel_bench,
    roofline_report,
    table1_opcounts,
    table2_accuracy,
    table4_ablation,
    table5_energy,
    table6_energy_network,
)

MODULES = [
    ("table1", table1_opcounts),
    ("table2", table2_accuracy),
    ("table4", table4_ablation),
    ("fig7", fig7_are),
    ("table5", table5_energy),
    ("table6", table6_energy_network),
    ("roofline", roofline_report),
    ("kernels", kernel_bench),
]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help=f"run one module: {', '.join(n for n, _ in MODULES)}")
    args = ap.parse_args(argv)
    known = [n for n, _ in MODULES]
    if args.only and args.only not in known:
        # a typo must not silently run nothing and exit green
        print(f"--only {args.only!r} is not a benchmark module; "
              f"have: {', '.join(known)}", file=sys.stderr)
        sys.exit(2)
    print("name,us_per_call,derived")
    failed: list[str] = []
    for name, mod in MODULES:
        if args.only and args.only != name:
            continue
        try:
            for row in mod.run(quick=not args.full):
                if isinstance(row, dict):  # rich rows (kernel_bench/table2)
                    us = row.get("us_per_call")  # bytes-model rows carry none
                    us_s = f"{us:.1f}" if us is not None else ""
                    print(f'{row["name"]},{us_s},"{row["derived"]}"',
                          flush=True)
                else:
                    row_name, us, derived = row
                    print(f'{row_name},{us:.1f},"{derived}"', flush=True)
        except Exception:  # noqa: BLE001
            failed.append(name)
            traceback.print_exc()
            print(f'{name}/FAILED,0,"see stderr"', flush=True)
    if failed:
        # explicit propagation: the job fails and names the failing modules
        print(f"FAILED modules: {', '.join(failed)}", file=sys.stderr)
        sys.exit(1)


def run_all():
    main()


if __name__ == "__main__":
    main()
