"""Benchmark driver: one module per paper table/figure + roofline + kernels.

Prints ``name,us_per_call,derived`` CSV (scaffold contract).  ``--full`` runs
the longer training-proxy settings.
"""
import argparse
import sys
import traceback

from benchmarks import (
    fig7_are,
    kernel_bench,
    roofline_report,
    table1_opcounts,
    table2_accuracy,
    table4_ablation,
    table5_energy,
    table6_energy_network,
)

MODULES = [
    ("table1", table1_opcounts),
    ("table2", table2_accuracy),
    ("table4", table4_ablation),
    ("fig7", fig7_are),
    ("table5", table5_energy),
    ("table6", table6_energy_network),
    ("roofline", roofline_report),
    ("kernels", kernel_bench),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    ok = True
    for name, mod in MODULES:
        if args.only and args.only != name:
            continue
        try:
            for row in mod.run(quick=not args.full):
                if isinstance(row, dict):  # rich rows (kernel_bench)
                    print(f'{row["name"]},{row["us_per_call"]:.1f},'
                          f'"{row["derived"]}"', flush=True)
                else:
                    row_name, us, derived = row
                    print(f'{row_name},{us:.1f},"{derived}"', flush=True)
        except Exception:  # noqa: BLE001
            ok = False
            traceback.print_exc()
            print(f'{name}/FAILED,0,"see stderr"', flush=True)
    if not ok:
        sys.exit(1)


def run_all():
    main()


if __name__ == "__main__":
    main()
