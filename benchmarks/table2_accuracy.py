"""Paper Table II (convergence proxy): train the same reduced ResNet-20 on
synthetic CIFAR under fp32 / MLS<2,4> / MLS<2,1> / fixed-point(Ex=0) and
compare loss+accuracy trajectories.  The paper's claim at full scale:
<2,1> keeps CIFAR accuracy within 1%; pure fixed-point at the same mantissa
widths degrades or diverges."""
import time

import jax
import jax.numpy as jnp

from repro.core import EMFormat, FMT_CIFAR, FMT_IMAGENET, QuantConfig
from repro.data import make_cifar_iterator
from repro.models.cnn import CNNConfig, apply_cnn, init_cnn
from repro.optim import sgdm_init, sgdm_update

VARIANTS = {
    "fp32": None,
    "mls_e2m4": QuantConfig(fmt=FMT_IMAGENET),
    "mls_e2m1": QuantConfig(fmt=FMT_CIFAR),
    "fix_e0m4": QuantConfig(fmt=EMFormat(0, 4)),  # no elem exponent
    "nogroup_e2m1": QuantConfig(fmt=FMT_CIFAR, grouping="none"),
}


def _train(qcfg, steps, seed=0):
    cfg = CNNConfig(arch="resnet20", num_classes=10, width_mult=0.25, in_hw=16)
    params = init_cnn(jax.random.key(seed), cfg)
    opt = sgdm_init(params)
    nxt, ds = make_cifar_iterator(batch=32, hw=16, seed=seed)

    @jax.jit
    def step(params, opt, batch, i):
        def loss_fn(p):
            logits = apply_cnn(p, batch["image"], cfg, qcfg,
                               jax.random.fold_in(jax.random.key(1), i))
            ll = jax.nn.log_softmax(logits)
            loss = -jnp.take_along_axis(ll, batch["label"][:, None], 1).mean()
            acc = (logits.argmax(-1) == batch["label"]).mean()
            return loss, acc

        (l, a), g = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt = sgdm_update(g, opt, params, lr=0.05)
        return params, opt, l, a

    accs, losses = [], []
    for i in range(steps):
        batch, ds = nxt(ds)
        params, opt, l, a = step(params, opt, batch, jnp.int32(i))
        losses.append(float(l))
        accs.append(float(a))
    k = max(1, len(accs) // 5)
    return sum(losses[-k:]) / k, sum(accs[-k:]) / k


def run(quick: bool = True):
    steps = 40 if quick else 300
    rows = []
    base_acc = None
    for name, qcfg in VARIANTS.items():
        t0 = time.perf_counter()
        loss, acc = _train(qcfg, steps)
        us = (time.perf_counter() - t0) * 1e6 / steps
        if name == "fp32":
            base_acc = acc
        drop = (base_acc - acc) if base_acc is not None else 0.0
        rows.append((f"table2/{name}", us,
                     f"loss={loss:.3f} acc={acc:.3f} drop={drop:+.3f}"))
    return rows
