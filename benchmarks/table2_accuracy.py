"""Paper Table II (convergence proxy): train the same reduced ResNet-20 on
synthetic CIFAR under fp32 / MLS<2,4> / MLS<2,1> / fixed-point(Ex=0) and
compare loss+accuracy trajectories.  The paper's claim at full scale:
<2,1> keeps CIFAR accuracy within 1%; pure fixed-point at the same mantissa
widths degrades or diverges.

The variants are frontier-sweep cells (``repro.sweep``) pinned to this
table's historical proxy shape (ResNet-20, hw=16, batch=32), so the table
and the nightly sweep can never disagree about what a cell trains.
Standalone, it writes a stamped JSON artifact for the CI perf trail::

    PYTHONPATH=src python benchmarks/table2_accuracy.py --json BENCH_table2.json
"""
import argparse

try:
    from benchmarks._record import make_payload, write_json
except ImportError:  # run as a script: benchmarks/ itself is sys.path[0]
    from _record import make_payload, write_json

from repro.sweep.grid import Cell
from repro.sweep.runner import run_cell

# name -> Cell kwargs on top of the Table II proxy shape
VARIANTS = {
    "fp32": {"fmt": "fp32"},
    "mls_e2m4": {"fmt": "mls_e2m4"},
    "mls_e2m1": {"fmt": "mls_e2m1"},
    "fix_e0m4": {"fmt": "fix_e0m4"},
    "nogroup_e2m1": {"fmt": "mls_e2m1", "grouping": "none"},
}


def run(quick: bool = True):
    steps = 40 if quick else 300
    rows = []
    base_acc = None
    for name, kw in VARIANTS.items():
        cell = Cell(arch="resnet20", batch=32, hw=16, width=0.25,
                    steps=steps, **kw)
        r = run_cell(cell)
        acc, loss = r["final_acc"], r["final_loss"]
        if name == "fp32":
            base_acc = acc
        drop = (base_acc - acc) if base_acc is not None else 0.0
        loss_s = "nan" if loss is None else f"{loss:.3f}"
        rows.append({
            "name": f"table2/{name}",
            "us_per_call": round(r["wall_time_s"] * 1e6 / steps, 1),
            "derived": f"loss={loss_s} acc={acc:.3f} drop={drop:+.3f}",
            "config_hash": r["config_hash"],
            "final_loss": loss,
            "final_acc": acc,
            "diverged": r["diverged"],
            "steps": steps,
        })
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true",
                    help="300-step proxy (the nightly setting)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write rows as a BENCH_*.json artifact")
    args = ap.parse_args()
    rows = run(quick=not args.full)
    for r in rows:
        print(f'{r["name"]},{r["us_per_call"]:.1f},"{r["derived"]}"', flush=True)
    if args.json:
        write_json(args.json, make_payload("table2_accuracy", rows,
                                           quick=not args.full))


if __name__ == "__main__":
    main()
