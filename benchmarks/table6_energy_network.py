"""Paper Table VI: whole-network training energy, fp32 vs FP8 vs MLS,
reproducing the 8.3-10.2x (vs fp32) and 1.9-2.3x (vs FP8) claims."""
import time

from repro.energy import efficiency_ratios, network_energy
from repro.models.cnn import CNNConfig

ARCHS = {
    "resnet18": CNNConfig(arch="resnet18", num_classes=1000, in_hw=224),
    "resnet34": CNNConfig(arch="resnet34", num_classes=1000, in_hw=224),
    "vgg16": CNNConfig(arch="vgg16", num_classes=1000, in_hw=224),
    "googlenet": CNNConfig(arch="googlenet", num_classes=1000, in_hw=224),
}


def run(quick: bool = True):
    rows = []
    for name, cfg in ARCHS.items():
        t0 = time.perf_counter()
        r = efficiency_ratios(cfg)
        mls = network_energy(cfg, "mls")
        fp32 = network_energy(cfg, "fp32")
        us = (time.perf_counter() - t0) * 1e6
        rows.append((
            f"table6/{name}", us,
            f"fp32={fp32['total_uj']:.0f}uJ mls={mls['total_uj']:.0f}uJ "
            f"ratio_fp32={r['vs_fp32']:.2f}x (paper 8.3-10.2) "
            f"ratio_fp8={r['vs_fp8']:.2f}x (paper 1.9-2.3)",
        ))
    return rows
